//! Quickstart: build a bipartite graph, run the paper's best GPU variant,
//! certify the result, and compare against Hopcroft–Karp.
//!
//! Run with: `cargo run --release --example quickstart`

use bimatch::gpu::GpuMatcher;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::seq::Hk;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;

fn main() {
    // 1. a power-law bipartite graph (rows/columns of a kron-style sparse
    //    matrix), ~16k vertices per side
    let g = Family::Kron.generate(16_000, 42);
    println!("graph: {} rows, {} cols, {} edges", g.nr, g.nc, g.n_edges());

    // 2. the common cheap-matching initialization (paper §4)
    let init = InitHeuristic::Cheap.run(&g);
    println!("cheap matching: {} edges", init.cardinality());

    // 3. the paper's winning GPU algorithm: APFB + GPUBFS-WR + CT
    let gpu = GpuMatcher::default();
    let t = Timer::start();
    let result = gpu.run_detached(&g, init.clone());
    let gpu_secs = t.elapsed_secs();

    // 4. certified maximum (validity + Berge maximality)
    result.matching.certify(&g).expect("GPU result must be a maximum matching");
    println!(
        "{}: |M| = {} in {:.4}s ({} phases, {} BFS kernel launches, {} repairs)",
        gpu.name(),
        result.matching.cardinality(),
        gpu_secs,
        result.stats.phases,
        result.stats.bfs_kernel_launches,
        result.stats.fixes,
    );

    // 5. sequential Hopcroft–Karp on the same initialization
    let t = Timer::start();
    let hk = Hk.run_detached(&g, init);
    let hk_secs = t.elapsed_secs();
    hk.matching.certify(&g).unwrap();
    println!("hk:  |M| = {} in {:.4}s ({} phases)", hk.matching.cardinality(), hk_secs, hk.stats.phases);

    assert_eq!(result.matching.cardinality(), hk.matching.cardinality());
    println!("agreement OK; GPU/HK wall ratio = {:.2}", hk_secs / gpu_secs.max(1e-9));
}
