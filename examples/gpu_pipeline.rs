//! The three-layer pipeline end to end: the L1 Pallas kernel inside the
//! L2 JAX APFB program, AOT-compiled to HLO text by `make artifacts`,
//! loaded and executed from Rust through PJRT — and cross-checked against
//! the native device simulator and Hopcroft–Karp.
//!
//! Run with: `make artifacts && cargo run --release --example gpu_pipeline`

use bimatch::gpu::xla_backend::{XlaApfbMatcher, XlaHybridMatcher};
use bimatch::gpu::GpuMatcher;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::runtime::Engine;
use bimatch::seq::Hk;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;
use std::sync::Arc;

fn main() {
    let engine = match Engine::open_default() {
        Ok(e) => Arc::new(e),
        Err(e) => {
            eprintln!("artifacts not found ({e:#}) — run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT platform: {}", engine.platform());
    println!("buckets: {:?}", engine.manifest().buckets());

    // a graph that fits the default 1024x1024x8 bucket
    let g = Family::Uniform.generate(1000, 9);
    println!("graph: {} x {}, {} edges, max col degree {}", g.nr, g.nc, g.n_edges(), g.max_col_degree());
    let init = InitHeuristic::Cheap.run(&g);

    // 1. whole matching as one XLA program (compile once, then execute)
    let xla = XlaApfbMatcher::new(engine.clone());
    let t = Timer::start();
    let r1 = xla.try_run(&g, &init).expect("apfb_full artifact run");
    let t_first = t.elapsed_secs();
    let t = Timer::start();
    let r1b = xla.try_run(&g, &init).expect("apfb_full artifact rerun");
    let t_warm = t.elapsed_secs();
    r1.matching.certify(&g).expect("XLA apfb_full must be maximum");
    assert_eq!(r1.matching.cardinality(), r1b.matching.cardinality());
    println!(
        "xla:apfb-full      |M| = {} ({} phases, {} launches)  first {:.3}s (incl. compile), warm {:.3}s",
        r1.matching.cardinality(),
        r1.stats.phases,
        r1.stats.bfs_kernel_launches,
        t_first,
        t_warm
    );

    // 2. hybrid: device BFS levels + host ALTERNATE
    let hybrid = XlaHybridMatcher::new(engine);
    let t = Timer::start();
    let r2 = hybrid.try_run(&g, &init).expect("bfs_level artifact run");
    let t2 = t.elapsed_secs();
    r2.matching.certify(&g).unwrap();
    println!(
        "xla:hybrid         |M| = {} ({} phases, {} launches)  {:.3}s",
        r2.matching.cardinality(),
        r2.stats.phases,
        r2.stats.bfs_kernel_launches,
        t2
    );

    // 3. native simulator + sequential reference
    let t = Timer::start();
    let r3 = GpuMatcher::default().run_detached(&g, init.clone());
    let t3 = t.elapsed_secs();
    r3.matching.certify(&g).unwrap();
    println!("native simulator   |M| = {} ({:.3}s)", r3.matching.cardinality(), t3);

    let r4 = Hk.run_detached(&g, init);
    println!("hopcroft-karp      |M| = {}", r4.matching.cardinality());

    assert_eq!(r1.matching.cardinality(), r4.matching.cardinality());
    assert_eq!(r2.matching.cardinality(), r4.matching.cardinality());
    assert_eq!(r3.matching.cardinality(), r4.matching.cardinality());
    println!("all four paths agree — three-layer pipeline OK");
}
