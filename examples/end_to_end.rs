//! End-to-end system driver (EXPERIMENTS.md §End-to-end): the coordinator
//! serving a realistic 200-job trace that mixes every generator family,
//! original and RCP-permuted instances, explicit algorithm choices and
//! auto-routing — with every result certified, under a batch-wide
//! deadline. Reports throughput, latency quantiles, per-algorithm win
//! counts, and the headline GPU-vs-sequential speedup on this trace. Also
//! exercises the TCP front end, including the incremental verbs
//! (LOAD/UPDATE/MATCH name=/DROP).
//!
//! Run with: `cargo run --release --example end_to_end`

use bimatch::coordinator::job::{GraphSource, MatchJob};
use bimatch::coordinator::{Server, Service};
use bimatch::graph::gen::Family;
use bimatch::runtime::Engine;
use bimatch::util::rng::Xoshiro256;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;

fn main() {
    let engine = Engine::open_default().ok().map(Arc::new);
    println!(
        "artifacts: {}",
        if engine.is_some() { "loaded (xla:* available)" } else { "absent (native only)" }
    );

    // ---- build the trace: 200 jobs ----
    let mut rng = Xoshiro256::new(2026);
    let algos = [
        None, // auto-routed
        None,
        Some("gpu:APFB-GPUBFS-WR-CT"),
        Some("pfp"),
        Some("hk"),
        Some("p-dbfs"),
    ];
    let mut jobs = Vec::new();
    for id in 0..200u64 {
        let family = Family::ALL[rng.gen_range(Family::ALL.len())];
        let n = 1000 + rng.gen_range(4000);
        let permute = rng.gen_bool(0.5);
        let mut job = MatchJob::new(
            id,
            GraphSource::Generate { family, n, seed: rng.next_u64() % 1000, permute },
        );
        if let Some(a) = algos[rng.gen_range(algos.len())] {
            job = job.with_algo(a);
        }
        jobs.push(job);
    }

    // ---- run through the service, under a batch-wide deadline ----
    // (the budget is generous — it exists to prove the whole trace runs
    // under one absolute deadline; a tripped job would surface as a
    // distinct DeadlineExceeded failure below, never a silently
    // suboptimal matching)
    let workers = bimatch::util::pool::default_threads();
    let svc = Service::start(workers, 16, engine.clone());
    let t = Timer::start();
    let (outcomes, metrics) = svc.run_batch_with_timeout_ms(jobs, 600_000);
    let wall = t.elapsed_secs();

    assert_eq!(outcomes.len(), 200);
    let failed: Vec<_> = outcomes.iter().filter(|o| o.error.is_some()).collect();
    assert!(failed.is_empty(), "failures: {failed:?}");
    assert!(outcomes.iter().all(|o| o.certified), "every job must be certified maximum");
    assert_eq!(
        metrics.jobs_timed_out.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "the 10-minute batch budget must not trip on this trace"
    );

    println!("\n=== trace results ===");
    println!("{}", metrics.report());
    println!(
        "throughput: {:.1} jobs/s ({} workers), wall {:.2}s",
        200.0 / wall,
        workers,
        wall
    );
    let edges: usize = outcomes.iter().map(|o| o.n_edges).sum();
    println!("total edges processed: {edges} ({:.1} Medges/s)", edges as f64 / wall / 1e6);

    // per-algorithm breakdown
    let mut by_algo: HashMap<String, (usize, f64)> = HashMap::new();
    for o in &outcomes {
        let e = by_algo.entry(o.algo.clone()).or_default();
        e.0 += 1;
        e.1 += o.t_match;
    }
    let mut t = Table::new(vec!["algorithm", "jobs", "total match s", "mean ms"]);
    let mut rows: Vec<_> = by_algo.into_iter().collect();
    rows.sort_by(|a, b| a.0.cmp(&b.0));
    for (algo, (n, secs)) in rows {
        t.row(vec![algo, n.to_string(), format!("{secs:.3}"), format!("{:.2}", secs * 1e3 / n as f64)]);
    }
    println!("\n{}", t.render());

    // headline: GPU vs sequential on the auto+explicit GPU jobs, matched
    // against HK on the same graphs (re-run quickly through the executor)
    let gpu_jobs: Vec<&bimatch::coordinator::MatchOutcome> = outcomes
        .iter()
        .filter(|o| o.algo.starts_with("gpu:"))
        .collect();
    println!(
        "GPU-algorithm jobs: {} of 200 (router sends big non-banded graphs to the GPU)",
        gpu_jobs.len()
    );

    // ---- TCP front end ----
    let server = Server::bind("127.0.0.1:0", engine).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    for req in [
        "MATCH family=kron n=2000 seed=7 algo=auto",
        "MATCH family=banded n=3000 seed=1",
        "MATCH family=road n=2000 seed=2 permute=1 algo=gpu:APFB-GPUBFS-WR-CT",
        "STATS",
    ] {
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let reader = BufReader::new(s.try_clone().unwrap());
    println!("\n=== TCP front end ===");
    for (i, line) in reader.lines().enumerate() {
        let line = line.unwrap();
        println!("  {line}");
        assert!(line.starts_with("OK") || line.starts_with("STATS"), "{line}");
        if i == 3 {
            break;
        }
    }

    // ---- incremental verbs: a graph living server-side across requests ----
    println!("\n=== incremental (LOAD/UPDATE/MATCH/DROP) ===");
    for req in [
        "LOAD name=live family=road n=3000 seed=5",
        "MATCH name=live",
        "UPDATE name=live addcols=0;1;2 del=0:0",
        "MATCH name=live",
        "STATS",
        "DROP name=live",
    ] {
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let reader = BufReader::new(s.try_clone().unwrap());
    for (i, line) in reader.lines().enumerate() {
        let line = line.unwrap();
        println!("  {line}");
        assert!(line.starts_with("OK") || line.starts_with("STATS"), "{line}");
        if i == 5 {
            break;
        }
    }
    s.write_all(b"QUIT\n").unwrap();
    println!("\nend_to_end OK");
}
