//! The paper's motivating application (§1): maximum transversal inside a
//! sparse direct solver. A maximum matching of the matrix's bipartite
//! graph puts nonzeros on the diagonal; `bimatch::apps::btf` then derives
//! the block-triangular form — if the matrix is reducible, the solver
//! factors only the diagonal blocks ("substantial savings in computational
//! requirements", Duff–Erisman–Reid).
//!
//! Run with: `cargo run --release --example sparse_solver`

use bimatch::apps::btf;
use bimatch::gpu::GpuMatcher;
use bimatch::graph::{BipartiteCsr, EdgeList};
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::koenig::certify_with_cover;
use bimatch::util::rng::Xoshiro256;
use bimatch::MatchingAlgorithm;

/// A block-structured sparse matrix: `nblocks` diagonal blocks (dense-ish)
/// plus strictly upper off-block entries — structurally reducible, then
/// hidden by a random symmetric permutation.
fn reducible_matrix(nblocks: usize, block: usize, seed: u64) -> BipartiteCsr {
    let n = nblocks * block;
    let mut rng = Xoshiro256::new(seed);
    let mut el = EdgeList::new(n, n);
    for b in 0..nblocks {
        let base = b * block;
        for i in 0..block {
            el.add(base + i, base + i);
            for _ in 0..4 {
                el.add(base + i, base + rng.gen_range(block));
            }
        }
        // upper off-diagonal coupling to later blocks only (reducible)
        if b + 1 < nblocks {
            for _ in 0..block / 2 {
                let later = b + 1 + rng.gen_range(nblocks - b - 1);
                el.add(base + rng.gen_range(block), later * block + rng.gen_range(block));
            }
        }
    }
    let g = el.build();
    // hide the structure with one symmetric permutation (same on rows and
    // cols so the matrix stays reducible)
    let p = Xoshiro256::new(seed ^ 0xBEEF).permutation(n);
    bimatch::graph::permute::permute(&g, &p, &p)
}

fn main() {
    let (nblocks, block) = (24, 250);
    let a = reducible_matrix(nblocks, block, 7);
    let n = a.nc;
    println!("matrix: {n} x {n}, {} nonzeros (structure hidden by permutation)", a.n_edges());

    // 1. maximum transversal via the paper's GPU algorithm
    let init = InitHeuristic::KarpSipser.run(&a);
    let r = GpuMatcher::default().run_detached(&a, init);
    r.matching.certify(&a).unwrap();
    println!("maximum transversal: {}/{n}", r.matching.cardinality());

    // 2. independent optimality witness: König minimum vertex cover
    let cover = certify_with_cover(&a, &r.matching).expect("König certificate");
    println!("König cover: {} vertices (= |M|, optimality certified twice)", cover.size());

    // 3. BTF via SCC on the matched digraph
    let b = btf(&a, &r.matching).expect("structurally nonsingular");
    let largest = b.block_sizes.iter().copied().max().unwrap_or(0);
    println!(
        "block-triangular form: {} diagonal blocks, largest {largest}, reducible: {}",
        b.n_blocks(),
        b.is_reducible()
    );
    assert!(b.n_blocks() >= nblocks, "planted reducibility must be recovered");

    // 4. estimated savings: dense-LU cost model n^3 vs sum b_i^3
    println!("factorization cost model: {:.1}x savings from BTF", b.lu_savings(n));
}
