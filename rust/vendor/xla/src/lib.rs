//! Offline stub of the `xla` PJRT bindings. The build environment ships no
//! libxla, so [`PjRtClient::cpu`] reports "unavailable" and every caller
//! takes its documented fallback path (the native device simulator). The
//! API surface mirrors the subset `bimatch::runtime` uses, so swapping the
//! real crate back in is a one-line Cargo change.

use std::fmt;

/// Stub error: a message, Display + Debug like the real crate's error.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "PJRT unavailable: offline xla stub (libxla not present in this build)";

/// Host literal (opaque in the stub; buffers never reach a device).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Device-side buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub; callers fall back to the native simulator.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(UNAVAILABLE.into()))
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error(UNAVAILABLE.into()))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn literal_roundtrip_is_inert() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3]).is_ok());
    }
}
