//! Offline stand-in for the `log` crate: the five level macros, printing
//! `LEVEL message` lines to stderr (no logger registry — the binary has a
//! single consumer, the terminal).

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { eprintln!("ERROR {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { eprintln!("WARN {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { eprintln!("INFO {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if std::env::var("BIMATCH_DEBUG").is_ok() {
            eprintln!("DEBUG {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        if std::env::var("BIMATCH_TRACE").is_ok() {
            eprintln!("TRACE {}", format!($($arg)*));
        }
    };
}
