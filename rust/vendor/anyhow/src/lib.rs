//! Offline stand-in for the `anyhow` crate: the subset this repository
//! uses (`anyhow!`, `bail!`, `Context`, `Result`) with the same observable
//! formatting contract — `{}` prints the outermost message, `{:#}` prints
//! the whole context chain joined by `": "`.

use std::fmt;

/// Error with a context chain; `msgs[0]` is the outermost message.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(context: C, inner: String) -> Error {
        Error { msgs: vec![context.to_string(), inner] }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.join(": "))
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error or a missing value, as in `anyhow`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        // `{:#}` so wrapping our own Error keeps its full chain
        self.map_err(|e| Error::wrap(context, format!("{e:#}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::wrap(f(), format!("{e:#}")))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("inner {}", 7))
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn chain_of_three() {
        let e = fails().context("mid").context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing x");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(f(0).is_err());
        assert_eq!(f(2).unwrap(), 2);
    }
}
