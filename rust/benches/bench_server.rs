//! Server saturation study: sustained MATCH/UPDATE throughput against a
//! live TCP server as the connection count climbs. One in-process server
//! (in-memory, no durability — this measures the coordinator and wire
//! path, not fsync) holds a preloaded graph; for each connection count
//! C ∈ {1, 2, 4, 8} (smoke: {1, 2}) we run C client threads for a fixed
//! window, each issuing a 3:1 MATCH:UPDATE mix on its own connection and
//! requiring an `OK` acknowledgement before the next request, then
//! report aggregate and per-connection ops/sec.
//!
//! The UPDATE is an insert of a fixed pair: the first one lands, every
//! later one is a rejected no-op, so the graph is stable across the
//! whole study and every MATCH answers for the same instance.
//!
//! Asserts: every reply on every connection is `OK`, and every window
//! completes at least one request per connection.
//!
//! Run with: `cargo bench --bench bench_server` (BIMATCH_SMOKE=1 for the
//! CI-sized run).

mod common;

use bimatch::coordinator::Server;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn client_window(addr: SocketAddr, stop: &AtomicBool, seq: &mut u64) -> u64 {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_nodelay(true).ok();
    let mut reader = BufReader::new(s.try_clone().expect("clone"));
    let mut line = String::new();
    let mut ops = 0u64;
    while !stop.load(Ordering::Relaxed) {
        *seq += 1;
        let req = if *seq % 4 == 0 {
            // rejected no-op after the very first landing — keeps the
            // graph identical for every MATCH in the study
            "UPDATE name=g add=0:0\n"
        } else {
            "MATCH name=g\n"
        };
        s.write_all(req.as_bytes()).expect("write");
        line.clear();
        reader.read_line(&mut line).expect("read");
        assert!(line.starts_with("OK "), "request {req:?} got {line:?}");
        ops += 1;
    }
    s.write_all(b"QUIT\n").ok();
    ops
}

fn main() {
    let smoke = std::env::var("BIMATCH_SMOKE").is_ok();
    let n = if smoke { 300 } else { 1_500 };
    let window = if smoke { Duration::from_millis(300) } else { Duration::from_secs(1) };
    let conn_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };

    let server = Server::bind("127.0.0.1:0", None).expect("bind");
    let addr = server.local_addr().expect("addr");
    std::thread::spawn(move || server.serve());

    // preload the shared graph and wait for the server to answer
    {
        let mut s = TcpStream::connect(addr).expect("connect");
        s.write_all(format!("LOAD name=g family=uniform n={n} seed=7\nQUIT\n").as_bytes())
            .expect("load");
        let mut reply = String::new();
        BufReader::new(s).read_line(&mut reply).expect("load reply");
        assert!(reply.starts_with("OK "), "LOAD got {reply:?}");
    }

    let mut t = Table::new(vec!["conns", "window s", "ops", "ops/s", "ops/s per conn"]);
    let mut telemetry = common::Report::new("bench_server");

    for &conns in conn_counts {
        let stop = Arc::new(AtomicBool::new(false));
        let timer = Timer::start();
        let workers: Vec<_> = (0..conns)
            .map(|i| {
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut seq = i as u64; // stagger the MATCH/UPDATE mix
                    client_window(addr, &stop, &mut seq)
                })
            })
            .collect();
        std::thread::sleep(window);
        stop.store(true, Ordering::Relaxed);
        let per_conn: Vec<u64> = workers.into_iter().map(|w| w.join().expect("client")).collect();
        let secs = timer.elapsed_secs();
        let total: u64 = per_conn.iter().sum();
        assert!(
            per_conn.iter().all(|&c| c >= 1),
            "every connection must complete at least one request ({per_conn:?})"
        );
        let rate = total as f64 / secs.max(1e-9);
        telemetry.metric(&format!("ops_per_sec.C{conns}"), rate, "ops/s", true);
        t.row(vec![
            conns.to_string(),
            format!("{secs:.3}"),
            total.to_string(),
            format!("{rate:.0}"),
            format!("{:.0}", rate / conns as f64),
        ]);
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nSustained MATCH/UPDATE (3:1 mix, one in-flight request per connection)\n\
         against a live in-memory server on a preloaded uniform n={n} graph;\n\
         every reply acknowledged OK. Each window ran {:.2}s.",
        window.as_secs_f64()
    ));
    common::emit("server saturation: ops/sec vs connection count (bench_server)", &body);
    telemetry.finish();
}
