//! Durability subsystem smoke/bench: (a) write-ahead-log append
//! throughput — raw fsync'd frame appends, and end-to-end durable
//! UPDATEs through the executor (apply + seeded repair + WAL fsync per
//! acknowledgement); (b) recovery-via-repair vs a cold recompute on the
//! same graph, across 3 generator families.
//!
//! The recovery side is the subsystem's headline: a restarted server
//! replays the WAL tail and *repairs* the snapshotted matching seeded
//! from the replayed exposed columns, instead of recomputing from cheap
//! init — asserted here as identical cardinality and no more phases than
//! the cold run (strictly fewer whenever the cold run does real
//! multi-phase work).
//!
//! Run with: `cargo bench --bench bench_persist` (BIMATCH_SMOKE=1 for
//! the CI-sized run).

mod common;

use bimatch::coordinator::job::{GraphSource, MatchJob};
use bimatch::coordinator::{registry, router, Executor, Metrics};
use bimatch::dynamic::DeltaBatch;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::persist::{wal, Persistence};
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;
use std::sync::Arc;

const FAMILIES: [Family; 3] = [Family::Road, Family::Kron, Family::Uniform];

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bimatch_bench_persist_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn main() {
    let smoke = std::env::var("BIMATCH_SMOKE").is_ok();
    let n = if smoke { 800 } else { 4_000 };
    let batches = if smoke { 15 } else { 100 };
    let raw_appends = if smoke { 200 } else { 2_000 };

    // -- raw WAL append throughput: fsync-bound frame appends ------------
    let dir = temp_dir("raw");
    let wal_path = dir.join("raw.wal");
    let frame = wal::WalRecord::Update {
        version_after: 1,
        batch_wire: "add=0:1,2:3 del=4:5".into(),
        report_wire: "ins=0:1,2:3 del=4:5 cols= rows= rejected=0 rebuilt=0".into(),
    };
    let t_raw = Timer::start();
    for _ in 0..raw_appends {
        wal::append(&wal_path, &frame).expect("raw append");
    }
    let raw_secs = t_raw.elapsed_secs();
    let (records, torn) = wal::read_wal(&wal_path).unwrap();
    assert_eq!(records.len(), raw_appends, "every appended frame must read back");
    assert!(!torn);
    let _ = std::fs::remove_dir_all(&dir);
    let mut telemetry = common::Report::new("bench_persist");
    telemetry.metric("wal_appends_per_sec", raw_appends as f64 / raw_secs.max(1e-9), "ops/s", true);

    let mut t = Table::new(vec![
        "family",
        "n",
        "edges",
        "durable upd",
        "upd/s",
        "replayed",
        "seeds",
        "repair phases",
        "cold phases",
        "recover s",
        "cold s",
        "card",
    ]);

    for fam in FAMILIES {
        let dir = temp_dir(fam.name());
        let g0 = Arc::new(fam.generate(n, 17));
        let edges = g0.edges();
        // enough distinct non-edges for one insert per batch
        let mut non_edges = Vec::new();
        'scan: for r in 0..g0.nr as u32 {
            for c in 0..g0.nc as u32 {
                if !g0.has_edge(r as usize, c as usize) {
                    non_edges.push((r, c));
                    if non_edges.len() > batches + 8 {
                        break 'scan;
                    }
                }
            }
        }
        let e = Executor::new(None, Arc::new(Metrics::new()))
            .with_persistence(Arc::new(Persistence::open(&dir).unwrap()));
        let mut id = 0u64;
        let mut bump = || {
            id += 1;
            id
        };
        let out = e.execute(&MatchJob::load_graph(bump(), "g", GraphSource::InMemory(g0.clone())));
        assert!(out.error.is_none(), "{:?}", out.error);
        let out = e.execute(&MatchJob::new(bump(), GraphSource::Stored("g".into())));
        assert!(out.certified, "{:?}", out.error);

        // -- durable update throughput: each iteration is one acknowledged
        // UPDATE — apply + repair + one fsync'd WAL frame
        let t_upd = Timer::start();
        for i in 0..batches {
            let (dr, dc) = edges[(i * 7) % edges.len()];
            let (ir, ic) = non_edges[i];
            let batch = DeltaBatch::new().delete(dr, dc).insert(ir, ic).insert(dr, dc);
            let out = e.execute(&MatchJob::update_graph(bump(), "g", batch));
            assert!(out.error.is_none(), "{} update {i}: {:?}", fam.name(), out.error);
        }
        let upd_secs = t_upd.elapsed_secs();

        // snapshot (with the maintained matching), then a short WAL tail
        // for recovery to replay through seeded repair
        let out = e.execute(&MatchJob::save_graph(bump(), "g"));
        assert!(out.error.is_none(), "{:?}", out.error);
        for i in 0..4usize {
            let (ir, ic) = non_edges[batches + 1 + i];
            let (dr, dc) = edges[(i * 131 + 5) % edges.len()];
            let batch = DeltaBatch::new().insert(ir, ic).delete(dr, dc);
            let out = e.execute(&MatchJob::update_graph(bump(), "g", batch));
            assert!(out.error.is_none(), "{:?}", out.error);
        }
        let final_card =
            e.execute(&MatchJob::new(bump(), GraphSource::Stored("g".into()))).cardinality;
        drop(e); // "crash"

        // -- recovery via seeded repair vs cold recompute ----------------
        let e2 = Executor::new(None, Arc::new(Metrics::new()))
            .with_persistence(Arc::new(Persistence::open(&dir).unwrap()));
        let t_rec = Timer::start();
        let report = e2.recover().unwrap();
        let rec_secs = t_rec.elapsed_secs();
        assert_eq!(report.recovered(), 1, "skipped: {:?}", report.skipped);
        let gr = &report.graphs[0];
        assert!(gr.clean);
        let repair_phases = gr.repair_phases.expect("recovery must repair the matching");
        assert_eq!(gr.cardinality, Some(final_card), "{}", fam.name());

        let live = e2.store().graph_for_match("g").unwrap().graph;
        let spec = router::route_graph(&live);
        let algo = registry::build(&spec, None).unwrap();
        let t_cold = Timer::start();
        let cold = algo.run_detached(&live, InitHeuristic::Cheap.run(&live));
        let cold_secs = t_cold.elapsed_secs();
        cold.matching.certify(&live).expect("cold recompute must be maximum");
        assert_eq!(cold.matching.cardinality(), final_card, "{}", fam.name());
        assert!(
            repair_phases <= cold.stats.phases,
            "{}: recovery repair took {repair_phases} phases, cold {}",
            fam.name(),
            cold.stats.phases
        );
        if cold.stats.phases >= 3 {
            assert!(
                repair_phases < cold.stats.phases,
                "{}: multi-phase cold run ({}) must beat the seeded repair ({repair_phases})",
                fam.name(),
                cold.stats.phases
            );
        }

        telemetry.metric(
            &format!("durable_updates_per_sec.{}", fam.name()),
            batches as f64 / upd_secs.max(1e-9),
            "ops/s",
            true,
        );
        telemetry.metric(&format!("recover_secs.{}", fam.name()), rec_secs, "s", false);
        t.row(vec![
            fam.name().to_string(),
            n.to_string(),
            live.n_edges().to_string(),
            batches.to_string(),
            format!("{:.0}", batches as f64 / upd_secs.max(1e-9)),
            gr.replayed_updates.to_string(),
            gr.seeds.to_string(),
            repair_phases.to_string(),
            cold.stats.phases.to_string(),
            format!("{rec_secs:.4}"),
            format!("{cold_secs:.4}"),
            final_card.to_string(),
        ]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nRaw WAL appends: {raw_appends} fsync'd frames in {raw_secs:.3}s \
         ({:.0} appends/s). Durable updates pay apply + seeded repair + one\n\
         fsync'd frame before the acknowledgement. Recovery = newest snapshot +\n\
         WAL-tail replay + repair seeded from the replayed exposed columns;\n\
         asserted to reach the identical cardinality as (and no more phases\n\
         than) a cold cheap-init recompute on the recovered graph.",
        raw_appends as f64 / raw_secs.max(1e-9)
    ));
    common::emit("WAL append throughput + recovery-via-repair (bench_persist)", &body);
    telemetry.finish();
}
