//! Table 2 — actual running time on the HardestK set: the best GPU
//! algorithm (APFB-GPUBFS-WR-CT) vs the best multicore baseline (P-DBFS)
//! vs the sequential PFP and HK, on both original and permuted variants.
//!
//! Expected shape (paper §4): GPU fastest on most rows; PFP near-instant
//! on the banded originals (Hamrle3 analogue) while HK struggles there;
//! permutation hurting PFP/HK far more than the GPU algorithm.

mod common;

use bimatch::util::table::{fmt_secs, Table};

const ALGOS: [&str; 4] = ["gpu:APFB-GPUBFS-WR-CT", "p-dbfs", "pfp", "hk"];

fn main() {
    let mut e = common::env();
    println!("Table 2 reproduction (scale={})", e.scale.name());
    let (_, o_hard, _, _) = common::paper_sets(&mut e);

    let mut t = Table::new(vec![
        "instance", "GPU", "P-DBFS", "PFP", "HK", "GPU(rcp)", "P-DBFS(rcp)", "PFP(rcp)", "HK(rcp)",
    ]);
    for inst in &o_hard {
        let mut row = vec![inst.name()];
        for variant in [*inst, inst.rcp()] {
            for algo in ALGOS {
                let r = e.evaluator.measure(&variant, algo);
                row.push(fmt_secs(r.wall_secs));
            }
        }
        t.row(row);
    }
    common::emit(
        "Table 2 (actual running time, HardestK, original + permuted)",
        &t.render(),
    );

    // count GPU wins as the paper reports them
    let mut gpu_best_orig = 0usize;
    let mut gpu_best_rcp = 0usize;
    for inst in &o_hard {
        for (variant, counter) in [(*inst, &mut gpu_best_orig), (inst.rcp(), &mut gpu_best_rcp)] {
            let times: Vec<f64> = ALGOS
                .iter()
                .map(|a| e.evaluator.measure(&variant, a).wall_secs)
                .collect();
            if times[0] <= times[1..].iter().cloned().fold(f64::INFINITY, f64::min) {
                *counter += 1;
            }
        }
    }
    common::emit(
        "Table 2 summary",
        &format!(
            "GPU fastest on {gpu_best_orig}/{} original and {gpu_best_rcp}/{} permuted hardest instances\n",
            o_hard.len(),
            o_hard.len()
        ),
    );
}
