//! Frontier-compaction ablation: FullScan (the paper's all-`nc` kernel
//! launches) vs Compacted (worklist-driven sweeps) across every generator
//! family, for the two headline drivers. Reports modeled device time
//! (serial and parallel views), edges scanned, the frontier sizes the
//! compacted run actually consumed, and wall-clock — and asserts the two
//! modes reach identical cardinality on every instance.
//!
//! Run with: `cargo bench --bench bench_frontier` (BIMATCH_SCALE=large for
//! the bigger catalog sizes).

mod common;

use bimatch::gpu::{ApDriver, GpuConfig, GpuMatcher};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;

struct ModeRun {
    device_ms: f64,
    device_parallel_ms: f64,
    edges: u64,
    frontier_peak: u64,
    frontier_total: u64,
    wall: f64,
    cardinality: usize,
}

fn run_mode(cfg: GpuConfig, g: &bimatch::graph::BipartiteCsr, init: &bimatch::matching::Matching) -> ModeRun {
    let t = Timer::start();
    let r = GpuMatcher::new(cfg).run(g, init.clone());
    let wall = t.elapsed_secs();
    ModeRun {
        device_ms: r.stats.device_cycles as f64 / 1e6,
        device_parallel_ms: r.stats.device_parallel_cycles as f64 / 1e6,
        edges: r.stats.edges_scanned,
        frontier_peak: r.stats.frontier_peak,
        frontier_total: r.stats.frontier_total,
        wall,
        cardinality: r.matching.cardinality(),
    }
}

fn main() {
    let e = common::env();
    let n = if e.scale.name() == "large" { 16_000 } else { 4_000 };
    let drivers = [(ApDriver::Apfb, "APFB"), (ApDriver::Apsb, "APsB")];

    let mut t = Table::new(vec![
        "family",
        "driver",
        "|M|",
        "dev ms FS",
        "dev ms FC",
        "FS/FC",
        "edges FS",
        "edges FC",
        "peak |F|",
        "total |F|",
        "wall FS s",
        "wall FC s",
    ]);
    let mut fc_wins = 0usize;
    let mut fc_parallel_wins = 0usize;
    let mut total = 0usize;

    for fam in Family::ALL {
        let g = fam.generate(n, 13);
        let init = InitHeuristic::Cheap.run(&g);
        for (driver, dname) in drivers {
            let base = GpuConfig { driver, ..GpuConfig::default() };
            let fs = run_mode(base, &g, &init);
            let fc = run_mode(base.compacted(), &g, &init);
            assert_eq!(
                fs.cardinality, fc.cardinality,
                "{dname} on {}: modes must agree",
                fam.name()
            );
            total += 1;
            if fc.device_ms < fs.device_ms {
                fc_wins += 1;
            }
            if fc.device_parallel_ms < fs.device_parallel_ms {
                fc_parallel_wins += 1;
            }
            t.row(vec![
                fam.name().to_string(),
                dname.to_string(),
                fs.cardinality.to_string(),
                format!("{:.3}", fs.device_ms),
                format!("{:.3}", fc.device_ms),
                format!("{:.2}x", fs.device_ms / fc.device_ms.max(1e-9)),
                fs.edges.to_string(),
                fc.edges.to_string(),
                fc.frontier_peak.to_string(),
                fc.frontier_total.to_string(),
                format!("{:.4}", fs.wall),
                format!("{:.4}", fc.wall),
            ]);
        }
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nCompacted wins modeled device time on {fc_wins}/{total} (family, driver) cells \
         (parallel view: {fc_parallel_wins}/{total}) at n={n}; identical cardinality on all.\n\
         peak/total |F| are the worklist sizes the compacted sweeps consumed — the\n\
         full-scan runs paid nc={n}-ish per launch regardless.",
    ));
    common::emit("frontier compaction ablation (FullScan vs Compacted)", &body);

    assert!(
        fc_wins > 0,
        "compaction must win modeled device time on at least one sparse family"
    );
}
