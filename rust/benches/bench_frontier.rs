//! Frontier-compaction × execution-mode ablation: {FullScan, Compacted,
//! Adaptive} × {serial, device-parallel} across every generator family,
//! for the two headline drivers. FullScan is the paper's all-`nc` kernel
//! launch (plus ALTERNATE's all-`nr` endpoint scan); Compacted drives
//! both from worklists; Adaptive switches per phase — dense phase-seed
//! frontiers run FullScan, sparse ones (density below
//! `1/ADAPTIVE_DENSITY_DIV`) run Compacted; the parallel cells run every
//! kernel on host threads with the racy ones going through the atomic
//! CAS substrate (CAS charges included in their modeled time). Reports
//! modeled device time, edges scanned, the worklist sizes the compacted
//! runs consumed, and wall-clock — and asserts all cells reach identical
//! cardinality on every instance, backing the router's promotion of the
//! "-FC" twin to default GPU pick.
//!
//! Run with: `cargo bench --bench bench_frontier` (BIMATCH_SCALE=large
//! for the bigger catalog sizes, BIMATCH_SMOKE=1 for the CI-sized run).

mod common;

use bimatch::gpu::{ApDriver, GpuConfig, GpuMatcher};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;

const PAR_THREADS: usize = 4;

struct ModeRun {
    device_ms: f64,
    device_parallel_ms: f64,
    edges: u64,
    launches: u64,
    frontier_peak: u64,
    frontier_total: u64,
    endpoints_total: u64,
    wall: f64,
    cardinality: usize,
}

fn run_mode(
    cfg: GpuConfig,
    g: &bimatch::graph::BipartiteCsr,
    init: &bimatch::matching::Matching,
) -> ModeRun {
    let t = Timer::start();
    let r = GpuMatcher::new(cfg).run_detached(g, init.clone());
    let wall = t.elapsed_secs();
    ModeRun {
        device_ms: r.stats.device_cycles as f64 / 1e6,
        device_parallel_ms: r.stats.device_parallel_cycles as f64 / 1e6,
        edges: r.stats.edges_scanned,
        launches: r.stats.bfs_kernel_launches,
        frontier_peak: r.stats.frontier_peak,
        frontier_total: r.stats.frontier_total,
        endpoints_total: r.stats.endpoints_total,
        wall,
        cardinality: r.matching.cardinality(),
    }
}

fn main() {
    let e = common::env();
    let n = if std::env::var("BIMATCH_SMOKE").is_ok() {
        800
    } else if e.scale.name() == "large" {
        16_000
    } else {
        4_000
    };
    let drivers = [(ApDriver::Apfb, "APFB"), (ApDriver::Apsb, "APsB")];

    let mut t = Table::new(vec![
        "family",
        "driver",
        "|M|",
        "FS ms",
        "FS-par ms",
        "FC ms",
        "FC-par ms",
        "AF ms",
        "FS/FC",
        "edges FS",
        "peak |F|",
        "total |F|",
        "endpts",
        "wall FS s",
        "wall FC-par s",
    ]);
    let mut fc_wins = 0usize;
    let mut fc_parallel_wins = 0usize;
    let mut af_tracks_best = 0usize;
    let mut total = 0usize;
    let mut telemetry = common::Report::new("bench_frontier");

    for fam in Family::ALL {
        let g = fam.generate(n, 13);
        let init = InitHeuristic::Cheap.run(&g);
        for (driver, dname) in drivers {
            let base = GpuConfig { driver, ..GpuConfig::default() };
            let fs = run_mode(base, &g, &init);
            let fsp = run_mode(GpuConfig { device_parallelism: PAR_THREADS, ..base }, &g, &init);
            let fc = run_mode(base.compacted(), &g, &init);
            let fcp = run_mode(
                GpuConfig { device_parallelism: PAR_THREADS, ..base.compacted() },
                &g,
                &init,
            );
            let af = run_mode(base.adaptive(), &g, &init);
            for (mode, r) in [("FS-par", &fsp), ("FC", &fc), ("FC-par", &fcp), ("AF", &af)] {
                assert_eq!(
                    fs.cardinality,
                    r.cardinality,
                    "{dname} on {}: {mode} must reach the serial FullScan cardinality",
                    fam.name()
                );
            }
            total += 1;
            if fc.device_ms < fs.device_ms {
                fc_wins += 1;
            }
            if fc.device_parallel_ms < fs.device_parallel_ms {
                fc_parallel_wins += 1;
            }
            telemetry.metric(
                &format!("compaction_speedup.{}.{dname}", fam.name()),
                fs.device_ms / fc.device_ms.max(1e-9),
                "x",
                true,
            );
            // the adaptive claim: switching per phase should land near
            // whichever pure mode is cheaper on this instance (10% slack;
            // the phase trajectories of the pure modes can differ, so
            // this is a reported tendency, not a hard bound)
            if af.device_ms <= fs.device_ms.min(fc.device_ms) * 1.10 {
                af_tracks_best += 1;
            }
            // the acceptance bar for the "-FC" router promotion: on
            // every family where the frontier actually shrinks (average
            // consumed frontier under half the graph's real nc per
            // launch — generators don't always produce nc == n),
            // Compacted+parallel must stay at or under FullScan serial
            // even after paying its CAS charges
            let shrank = fc.frontier_total * 2 < fc.launches * g.nc as u64;
            if shrank {
                assert!(
                    fcp.device_ms <= fs.device_ms,
                    "{dname} on {}: FC-par {:.3} ms must not exceed FS serial {:.3} ms",
                    fam.name(),
                    fcp.device_ms,
                    fs.device_ms
                );
            }
            t.row(vec![
                fam.name().to_string(),
                dname.to_string(),
                fs.cardinality.to_string(),
                format!("{:.3}", fs.device_ms),
                format!("{:.3}", fsp.device_ms),
                format!("{:.3}", fc.device_ms),
                format!("{:.3}", fcp.device_ms),
                format!("{:.3}", af.device_ms),
                format!("{:.2}x", fs.device_ms / fc.device_ms.max(1e-9)),
                fs.edges.to_string(),
                fc.frontier_peak.to_string(),
                fc.frontier_total.to_string(),
                fc.endpoints_total.to_string(),
                format!("{:.4}", fs.wall),
                format!("{:.4}", fcp.wall),
            ]);
        }
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nCompacted wins modeled device time on {fc_wins}/{total} (family, driver) cells \
         (device-parallel view: {fc_parallel_wins}/{total}) at n={n}; identical cardinality on\n\
         all cells including the host-parallel (atomic CAS) runs with {PAR_THREADS} threads.\n\
         peak/total |F| and endpts are the worklist sizes the compacted sweeps and the\n\
         compacted ALTERNATE consumed — the full-scan runs paid nc={n}-ish per BFS launch\n\
         and nr per ALTERNATE regardless.\n\
         Adaptive (-AF, FullScan while phase-seed density >= 1/8 of nc, Compacted after)\n\
         lands within 10% of the cheaper pure mode on {af_tracks_best}/{total} cells.",
    ));
    common::emit(
        "frontier compaction x execution mode ablation (FullScan/Compacted/Adaptive x serial/parallel)",
        &body,
    );

    telemetry.metric("fc_win_cells", fc_wins as f64, "count", true);
    telemetry.metric("af_tracks_best_cells", af_tracks_best as f64, "count", true);
    telemetry.finish();

    assert!(
        fc_wins > 0,
        "compaction must win modeled device time on at least one sparse family"
    );
}
