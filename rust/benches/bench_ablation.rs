//! Ablations of the design choices DESIGN.md calls out (§4 text claims
//! plus our own knobs):
//!
//! * CT vs MT thread mapping (paper: "CT always increases performance")
//! * GPUBFS-WR vs GPUBFS (paper: "GPUBFS-WR is always faster")
//! * write-arbitration order (Forward/Reverse/Shuffled) — result must stay
//!   optimal, work may shift (robustness of FIXMATCHING)
//! * init heuristic (none / cheap / Karp–Sipser) on end-to-end time

mod common;

use bimatch::gpu::{ApDriver, BfsKernel, GpuConfig, GpuMatcher, ThreadMapping, WriteOrder};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::reference_max_cardinality;
use bimatch::MatchingAlgorithm;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;

fn main() {
    let e = common::env();
    // > 65 536 columns so the CT grid actually amortizes (the paper's
    // CT-vs-MT effect only exists beyond the constant grid size)
    let n = if e.scale.name() == "large" { 300_000 } else { 100_000 };
    let graphs: Vec<(String, bimatch::graph::BipartiteCsr)> = [Family::Kron, Family::Road, Family::Banded]
        .iter()
        .map(|f| (f.name().to_string(), f.generate(n, 11)))
        .collect();

    // ---- CT vs MT and WR vs plain (modeled device ms) ----
    let mut t = Table::new(vec!["graph", "BFS-MT", "BFS-CT", "WR-MT", "WR-CT", "CT gain", "WR gain"]);
    for (name, g) in &graphs {
        let init = InitHeuristic::Cheap.run(g);
        let mut dev = Vec::new();
        for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
            for mapping in [ThreadMapping::Mt, ThreadMapping::Ct] {
                let cfg = GpuConfig { driver: ApDriver::Apfb, kernel, mapping, ..Default::default() };
                let (r, clock) = GpuMatcher::new(cfg).run_with_clock(
                    g,
                    init.clone(),
                    &mut bimatch::matching::algo::RunCtx::detached(),
                );
                r.matching.certify(g).unwrap();
                dev.push(clock.as_device_ms());
            }
        }
        t.row(vec![
            name.clone(),
            format!("{:.2}", dev[0]),
            format!("{:.2}", dev[1]),
            format!("{:.2}", dev[2]),
            format!("{:.2}", dev[3]),
            format!("{:.2}x", dev[2] / dev[3].max(1e-9)), // WR: MT/CT
            format!("{:.2}x", dev[1] / dev[3].max(1e-9)), // CT: plain/WR
        ]);
    }
    common::emit("Ablation A1a — mapping & kernel (modeled device ms, APFB)", &t.render());

    // ---- write-order robustness ----
    let mut t = Table::new(vec!["graph", "order", "card ok", "fixes", "fallbacks", "wall s"]);
    for (name, g) in &graphs {
        let want = reference_max_cardinality(g);
        for (oname, order) in [
            ("forward", WriteOrder::Forward),
            ("reverse", WriteOrder::Reverse),
            ("shuffled", WriteOrder::Shuffled),
        ] {
            let cfg = GpuConfig { write_order: order, seed: 0xAB1E, ..Default::default() };
            let init = InitHeuristic::Cheap.run(g);
            let timer = Timer::start();
            let r = GpuMatcher::new(cfg).run_detached(g, init);
            let wall = timer.elapsed_secs();
            r.matching.certify(g).unwrap();
            t.row(vec![
                name.clone(),
                oname.into(),
                (r.matching.cardinality() == want).to_string(),
                r.stats.fixes.to_string(),
                r.stats.fallbacks.to_string(),
                format!("{wall:.4}"),
            ]);
        }
    }
    common::emit("Ablation A1b — write-arbitration order", &t.render());

    // ---- init heuristic ablation (end-to-end = init + matching) ----
    let mut t = Table::new(vec!["graph", "init", "init card", "final card", "init s", "match s"]);
    for (name, g) in &graphs {
        for h in [InitHeuristic::None, InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
            let t0 = Timer::start();
            let init = h.run(g);
            let t_init = t0.elapsed_secs();
            let init_card = init.cardinality();
            let t1 = Timer::start();
            let r = GpuMatcher::default().run_detached(g, init);
            let t_match = t1.elapsed_secs();
            t.row(vec![
                name.clone(),
                h.name().into(),
                init_card.to_string(),
                r.matching.cardinality().to_string(),
                format!("{t_init:.4}"),
                format!("{t_match:.4}"),
            ]);
        }
    }
    common::emit("Ablation A1c — initialization heuristic", &t.render());
}
