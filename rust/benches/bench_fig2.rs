//! Figure 2 — BFS behaviour of APsB vs APFB on two contrasting graphs:
//! the number of BFS kernel launches (levels) in each outer iteration.
//!
//! Paper: Hamrle3 (banded) shows APFB converging in far fewer iterations
//! with more levels each (Fig. 2a); Delaunay-like meshes show APsB doing
//! many short iterations while APFB's levels balloon (Fig. 2b) — the one
//! regime where APsB wins.

mod common;

use bimatch::gpu::{ApDriver, BfsKernel, GpuConfig, GpuMatcher, ThreadMapping};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::MatchingAlgorithm;

fn series(driver: ApDriver, g: &bimatch::graph::BipartiteCsr) -> Vec<u32> {
    let cfg = GpuConfig {
        driver,
        kernel: BfsKernel::GpuBfsWr,
        mapping: ThreadMapping::Ct,
        ..Default::default()
    };
    let init = InitHeuristic::Cheap.run(g);
    let r = GpuMatcher::new(cfg).run_detached(g, init);
    r.stats.launches_per_phase
}

fn render(name: &str, apfb: &[u32], apsb: &[u32]) -> String {
    let mut out = format!(
        "{name}: x = outer iteration, y = BFS kernel launches in that iteration\n\
         APFB: {} iterations, {} total launches\n\
         APsB: {} iterations, {} total launches\n",
        apfb.len(),
        apfb.iter().sum::<u32>(),
        apsb.len(),
        apsb.iter().sum::<u32>()
    );
    let max = apfb.iter().chain(apsb).copied().max().unwrap_or(1).max(1);
    for (label, s) in [("APFB", apfb), ("APsB", apsb)] {
        out.push_str(&format!("{label} |"));
        for &v in s.iter().take(64) {
            let h = (v as usize * 8 / max as usize).min(8);
            out.push([' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'][h]);
        }
        if s.len() > 64 {
            out.push('…');
        }
        out.push('\n');
    }
    out
}

fn main() {
    let e = common::env();
    let scale = if e.scale.name() == "large" { 4 } else { 1 };
    // RCP variants: on the original orderings the cheap-matching init
    // leaves almost nothing to do (banded originals especially), so the
    // interesting BFS dynamics — the ones the paper plots — live on the
    // permuted instances.
    // Fig 2a analogue: banded circuit-like matrix (Hamrle3), permuted
    let banded =
        bimatch::graph::random_permute(&Family::Banded.generate(9_000 * scale, 2), 77);
    // Fig 2b analogue: triangulated mesh (delaunay_n23), permuted
    let mesh =
        bimatch::graph::random_permute(&Family::Delaunay.generate(9_000 * scale, 2), 77);

    for (name, g) in [("banded (Hamrle3-like)", &banded), ("delaunay mesh", &mesh)] {
        let apfb = series(ApDriver::Apfb, g);
        let apsb = series(ApDriver::Apsb, g);
        common::emit(&format!("Figure 2 — {name}"), &render(name, &apfb, &apsb));
        // paper claim: APFB converges in fewer (or equal) outer iterations
        assert!(
            apfb.len() <= apsb.len(),
            "{name}: APFB iterations {} > APsB {}",
            apfb.len(),
            apsb.len()
        );
    }
}
