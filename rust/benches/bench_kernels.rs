//! Kernel microbenchmarks (§Perf instrumentation): per-kernel wall-clock
//! of the native simulator's hot paths, plus the XLA-artifact level kernel
//! when `artifacts/` is present — quantifying the host↔device boundary
//! cost that DESIGN.md §Perf discusses.

mod common;

use bimatch::gpu::device::DeviceClock;
use bimatch::gpu::kernels::{alternate, fixmatching, gpubfs, gpubfs_wr, init_bfs_array, GpuState, LaunchCfg, L0};
use bimatch::gpu::{ThreadMapping, WriteOrder};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::Matching;
use bimatch::runtime::Engine;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use std::sync::Arc;

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    // one warmup, then best-of-reps (microbench convention)
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Timer::start();
        f();
        best = best.min(t.elapsed_secs());
    }
    best
}

fn main() {
    let e = common::env();
    let n = if e.scale.name() == "large" { 40_000 } else { 10_000 };
    let g = Family::Kron.generate(n, 5);
    let init = InitHeuristic::Cheap.run(&g);
    let cfg = LaunchCfg { mapping: ThreadMapping::Ct, order: WriteOrder::Forward, ..LaunchCfg::default() };
    let mut t = Table::new(vec!["kernel", "best secs", "per edge ns"]);
    let edges = g.n_edges() as f64;

    // INITBFSARRAY
    let mut st = GpuState::new(&g, &init);
    let mut clock = DeviceClock::default();
    let secs = bench(5, || init_bfs_array(&mut st, cfg, true, &mut clock));
    t.row(vec!["init_bfs_array".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);

    // GPUBFS first level (full frontier)
    init_bfs_array(&mut st, cfg, false, &mut clock);
    let base = st.clone();
    let secs = bench(5, || {
        st = base.clone();
        gpubfs(&g, &mut st, L0, cfg, &mut clock);
    });
    t.row(vec!["gpubfs (level L0)".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);

    // GPUBFS-WR first level
    let mut st2 = GpuState::new(&g, &init);
    init_bfs_array(&mut st2, cfg, true, &mut clock);
    let base2 = st2.clone();
    let secs = bench(5, || {
        st2 = base2.clone();
        gpubfs_wr(&g, &mut st2, L0, cfg, false, &mut clock);
    });
    t.row(vec!["gpubfs_wr (level L0)".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);

    // ALTERNATE + FIXMATCHING on a real mid-phase state
    let mut st3 = GpuState::new(&g, &init);
    init_bfs_array(&mut st3, cfg, false, &mut clock);
    let mut level = L0;
    loop {
        st3.vertex_inserted = false;
        gpubfs(&g, &mut st3, level, cfg, &mut clock);
        if !st3.vertex_inserted {
            break;
        }
        level += 1;
    }
    let base3 = st3.clone();
    let secs = bench(5, || {
        st3 = base3.clone();
        alternate(&mut st3, cfg, None, &mut clock);
    });
    t.row(vec!["alternate (full phase)".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);
    let base4 = st3.clone();
    let secs = bench(5, || {
        st3 = base4.clone();
        fixmatching(&mut st3, cfg, &mut clock);
    });
    t.row(vec!["fixmatching".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);

    // cheap init for reference
    let secs = bench(5, || {
        let _ = InitHeuristic::Cheap.run(&g);
    });
    t.row(vec!["cheap init (host)".into(), format!("{secs:.6}"), format!("{:.1}", secs * 1e9 / edges)]);

    common::emit("kernel microbenchmarks (native simulator)", &t.render());

    // XLA artifact path, if built
    match Engine::open_default() {
        Ok(engine) => {
            let engine = Arc::new(engine);
            let mut t = Table::new(vec!["xla path", "secs", "note"]);
            let small = Family::Uniform.generate(900, 3);
            let sinit = InitHeuristic::Cheap.run(&small);
            let m = bimatch::gpu::xla_backend::XlaApfbMatcher::new(engine.clone());
            match m.try_run(&small, &sinit) {
                Ok(_) => {
                    // compile is cached now; time pure execution
                    let secs = bench(3, || {
                        let _ = m.try_run(&small, &sinit);
                    });
                    t.row(vec![
                        "apfb_full artifact (n=900)".into(),
                        format!("{secs:.4}"),
                        "full matching on PJRT".into(),
                    ]);
                }
                Err(err) => {
                    t.row(vec!["apfb_full artifact".into(), "-".into(), format!("{err}")]);
                }
            }
            let h = bimatch::gpu::xla_backend::XlaHybridMatcher::new(engine);
            match h.try_run(&small, &sinit) {
                Ok(r) => {
                    let secs = bench(3, || {
                        let _ = h.try_run(&small, &sinit);
                    });
                    t.row(vec![
                        format!("bfs_level hybrid ({} launches)", r.stats.bfs_kernel_launches),
                        format!("{secs:.4}"),
                        "per-level host<->device".into(),
                    ]);
                }
                Err(err) => {
                    t.row(vec!["bfs_level hybrid".into(), "-".into(), format!("{err}")]);
                }
            }
            // native matcher on the same small graph, for the boundary-cost
            // comparison
            let native = bimatch::gpu::GpuMatcher::default();
            use bimatch::MatchingAlgorithm;
            let secs = bench(3, || {
                let _ = native.run_detached(&small, sinit.clone());
            });
            t.row(vec!["native simulator (same graph)".into(), format!("{secs:.4}"), String::new()]);
            common::emit("XLA artifact path", &t.render());
        }
        Err(e) => {
            common::emit(
                "XLA artifact path",
                &format!("artifacts not available ({e:#}); run `make artifacts` first\n"),
            );
        }
    }
}
