//! Table 1 — geometric mean running time of the 8 GPU variants
//! (APFB/APsB × GPUBFS/GPUBFS-WR × MT/CT) on the four instance sets
//! O_S1, O_HardestK, RCP_S1, RCP_HardestK.
//!
//! Two tables are printed: modeled device time (the cost model that
//! stands in for the C2050 — this is where the paper's CT>MT and WR>plain
//! orderings live) and host wall-clock of the simulator.
//!
//! Expected shape (paper §4): CT ≤ MT per variant; GPUBFS-WR ≤ GPUBFS;
//! APFB ≤ APsB overall; APFB-GPUBFS-WR-CT best overall.

mod common;

use bimatch::gpu::GpuConfig;
use bimatch::harness::report::geomean_over;
use bimatch::util::table::Table;

fn main() {
    let mut e = common::env();
    println!(
        "Table 1 reproduction (scale={}, S1 threshold={}s)",
        e.scale.name(),
        common::s1_threshold()
    );
    let (o_s1, o_hard, r_s1, r_hard) = common::paper_sets(&mut e);
    let variants: Vec<String> = GpuConfig::all_variants()
        .iter()
        .map(|c| format!("gpu:{}", c.name()))
        .collect();

    // measure all variants on the union of the sets
    let mut all_instances = Vec::new();
    for set in [&o_s1, &o_hard, &r_s1, &r_hard] {
        for i in set.iter() {
            if !all_instances.contains(i) {
                all_instances.push(*i);
            }
        }
    }
    let algo_names: Vec<&str> = variants.iter().map(|s| s.as_str()).collect();
    let records = e.evaluator.sweep(&all_instances, &algo_names);

    let sets = [
        ("O_S1", common::names(&o_s1)),
        ("O_Hardest", common::names(&o_hard)),
        ("RCP_S1", common::names(&r_s1)),
        ("RCP_Hardest", common::names(&r_hard)),
    ];

    for (title, use_device) in [("modeled device ms", true), ("host wall-clock s", false)] {
        let mut t = Table::new(vec![
            "set", "|set|",
            "APFB-BFS-MT", "APFB-BFS-CT", "APFB-WR-MT", "APFB-WR-CT",
            "APsB-BFS-MT", "APsB-BFS-CT", "APsB-WR-MT", "APsB-WR-CT",
        ]);
        for (set_name, insts) in &sets {
            let mut row = vec![set_name.to_string(), insts.len().to_string()];
            for v in [
                "gpu:APFB-GPUBFS-MT", "gpu:APFB-GPUBFS-CT",
                "gpu:APFB-GPUBFS-WR-MT", "gpu:APFB-GPUBFS-WR-CT",
                "gpu:APsB-GPUBFS-MT", "gpu:APsB-GPUBFS-CT",
                "gpu:APsB-GPUBFS-WR-MT", "gpu:APsB-GPUBFS-WR-CT",
            ] {
                let g = geomean_over(&records, v, insts, |r| {
                    if use_device { r.device_ms } else { r.wall_secs }
                });
                row.push(format!("{g:.3}"));
            }
            t.row(row);
        }
        common::emit(
            &format!("Table 1 ({title})"),
            &format!("geomean {title} per GPU variant\n{}", t.render()),
        );
    }

    // the paper's qualitative claims, checked programmatically
    let union_names: Vec<String> = all_instances.iter().map(|i| i.name()).collect();
    let dev = |v: &str| geomean_over(&records, v, &union_names, |r| r.device_ms);
    let mut claims = String::new();
    for (a, b, what) in [
        ("gpu:APFB-GPUBFS-WR-CT", "gpu:APFB-GPUBFS-WR-MT", "CT<=MT (APFB-WR)"),
        ("gpu:APFB-GPUBFS-CT", "gpu:APFB-GPUBFS-MT", "CT<=MT (APFB)"),
        ("gpu:APsB-GPUBFS-WR-CT", "gpu:APsB-GPUBFS-WR-MT", "CT<=MT (APsB-WR)"),
        ("gpu:APFB-GPUBFS-WR-CT", "gpu:APFB-GPUBFS-CT", "WR<=plain (APFB,CT)"),
        ("gpu:APsB-GPUBFS-WR-CT", "gpu:APsB-GPUBFS-CT", "WR<=plain (APsB,CT)"),
        ("gpu:APFB-GPUBFS-WR-CT", "gpu:APsB-GPUBFS-WR-CT", "APFB<=APsB (WR,CT)"),
    ] {
        let (da, db) = (dev(a), dev(b));
        claims.push_str(&format!(
            "{what}: {da:.3} vs {db:.3} -> {}\n",
            if da <= db * 1.05 { "HOLDS" } else { "VIOLATED" }
        ));
    }
    common::emit("Table 1 qualitative claims", &claims);
}
