//! Incremental repair vs from-scratch recompute: the dynamic subsystem's
//! headline claim. For each family × batch size, a maintained maximum
//! matching is hit with a delta batch (half deletions of *matched* edges,
//! half random insertions), then maximality is restored two ways:
//!
//! * **repair** — `dynamic::repair` warm-started from the maintained
//!   matching, seeded from the exposed columns, on the compacted-frontier
//!   GPU driver (`gpu:APFB-GPUBFS-WR-CT-FC`);
//! * **recompute** — the same driver from a fresh cheap-init on the
//!   mutated graph (what a stateless service pays per request).
//!
//! Reported cost is modeled device cycles (the simulator's wall-clock
//! stand-in; host-side patching is outside the device model for both
//! sides). The bench asserts repair ≡ recompute cardinality on every
//! cell, and that repair's modeled cost undercuts recompute on every
//! family for small batches (≤1% of edges) — the acceptance bar for the
//! subsystem.
//!
//! Run with: `cargo bench --bench bench_dynamic` (BIMATCH_SCALE=large for
//! bigger instances, BIMATCH_SMOKE=1 for the CI-sized run).

mod common;

use bimatch::coordinator::spec::AlgoSpec;
use bimatch::dynamic::{repair, DeltaBatch, DynamicGraph};
use bimatch::gpu::{GpuConfig, GpuMatcher};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::util::rng::Xoshiro256;
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use bimatch::{MatchingAlgorithm, RunCtx};

const FAMILIES: [Family; 3] = [Family::Road, Family::Kron, Family::Uniform];
const FRACTIONS: [f64; 3] = [0.001, 0.01, 0.05];

fn main() {
    let e = common::env();
    let n = if std::env::var("BIMATCH_SMOKE").is_ok() {
        800
    } else if e.scale.name() == "large" {
        16_000
    } else {
        4_000
    };
    let spec: AlgoSpec = "gpu:APFB-GPUBFS-WR-CT-FC".parse().unwrap();
    let matcher = GpuMatcher::new(GpuConfig::default().compacted());

    let mut t = Table::new(vec![
        "family",
        "batch",
        "frac",
        "|M| before",
        "|M| after",
        "seeds",
        "repair Mcyc",
        "recompute Mcyc",
        "speedup",
        "repair phases",
        "recomp phases",
        "wall repair s",
        "wall recomp s",
    ]);
    let mut small_batch_cells = 0usize;
    let mut telemetry = common::Report::new("bench_dynamic");

    for fam in FAMILIES {
        // the acceptance bar is per family: every family must contribute
        // at least one measurable small-batch cell where repair wins
        let mut family_cells = 0usize;
        let base = fam.generate(n, 13);
        let edges_total = base.n_edges();
        // the maintained maximum the service would be holding
        let maintained = matcher
            .run_detached(&base, InitHeuristic::Cheap.run(&base))
            .matching;
        maintained.certify(&base).expect("maintained matching must be maximum");

        for frac in FRACTIONS {
            let k = ((edges_total as f64 * frac / 2.0) as usize).max(1);
            let mut rng = Xoshiro256::new(0xDE17A ^ (k as u64));
            // k deletions of matched edges, spread across the columns
            let matched: Vec<usize> =
                (0..base.nc).filter(|&c| maintained.cmatch[c] >= 0).collect();
            let stride = (matched.len() / k.min(matched.len()).max(1)).max(1);
            let mut batch = DeltaBatch::new();
            for &c in matched.iter().step_by(stride).take(k) {
                batch = batch.delete(maintained.cmatch[c] as u32, c as u32);
            }
            // k random insertions (existing pairs become rejected no-ops)
            for _ in 0..k {
                batch = batch.insert(rng.gen_range(base.nr) as u32, rng.gen_range(base.nc) as u32);
            }

            let mut dg = DynamicGraph::new(base.clone());
            let report = dg.apply(&batch);
            let g = dg.snapshot();

            let wall_repair = Timer::start();
            let mut ctx = RunCtx::detached();
            let summary = repair(&g, maintained.clone(), &report, &spec, None, &mut ctx)
                .expect("repair must run");
            let wall_repair = wall_repair.elapsed_secs();
            summary.result.matching.certify(&g).expect("repair must restore maximality");

            let wall_recompute = Timer::start();
            let cheap = InitHeuristic::Cheap.run(&g);
            let cheap_card = cheap.cardinality();
            let recomputed = matcher.run_detached(&g, cheap);
            let wall_recompute = wall_recompute.elapsed_secs();
            recomputed.matching.certify(&g).expect("recompute must be maximum");

            assert_eq!(
                summary.result.matching.cardinality(),
                recomputed.matching.cardinality(),
                "{} frac={frac}: repair and recompute must agree",
                fam.name()
            );

            let rc = summary.result.stats.device_cycles;
            let fc = recomputed.stats.device_cycles;
            // repair wins when the maintained matching's deficiency
            // (≈ the batch) undercuts cheap-init's; when a degenerate
            // instance leaves recompute with ~no augmentation work the
            // comparison is meaningless — reported, never silently capped
            let recompute_deficiency = recomputed.matching.cardinality() - cheap_card;
            if frac <= 0.01 {
                if recompute_deficiency > 2 * k {
                    small_batch_cells += 1;
                    family_cells += 1;
                    assert!(
                        rc < fc,
                        "{} frac={frac}: repair {rc} cycles must undercut recompute {fc}",
                        fam.name()
                    );
                } else {
                    println!(
                        "note: {} frac={frac} skipped the win assert — cheap-init \
                         deficiency {recompute_deficiency} is within the batch size {k}",
                        fam.name()
                    );
                }
            }
            telemetry.metric(
                &format!("repair_speedup_cycles.{}@{frac}", fam.name()),
                fc as f64 / rc.max(1) as f64,
                "x",
                true,
            );
            t.row(vec![
                fam.name().to_string(),
                format!("{}", 2 * k),
                format!("{:.3}%", frac * 100.0),
                maintained.cardinality().to_string(),
                summary.result.matching.cardinality().to_string(),
                summary.seeds.to_string(),
                format!("{:.3}", rc as f64 / 1e6),
                format!("{:.3}", fc as f64 / 1e6),
                format!("{:.1}x", fc as f64 / rc.max(1) as f64),
                summary.result.stats.phases.to_string(),
                recomputed.stats.phases.to_string(),
                format!("{wall_repair:.4}"),
                format!("{wall_recompute:.4}"),
            ]);
        }
        assert!(
            family_cells >= 1,
            "{}: no measurable small-batch cell — the per-family acceptance bar \
             cannot be evaluated",
            fam.name()
        );
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nSmall batches (≤1% of edges): repair beat recompute on all \
         {small_batch_cells} measurable cells at n={n} (asserted — the dynamic\n\
         subsystem's acceptance bar; degenerate cells where cheap-init had no\n\
         deficiency to speak of are reported above and excluded). Repair = seeded\n\
         compacted-frontier augmentation warm-started from the maintained matching;\n\
         recompute = cheap-init + full run on the mutated graph. Cycles are the\n\
         serial device model in Mcycles.",
    ));
    common::emit("incremental repair vs from-scratch recompute (bench_dynamic)", &body);
    telemetry.metric("small_batch_cells", small_batch_cells as f64, "count", true);
    telemetry.finish();
}
