//! Sharded-execution scaling ablation: the headline GPU variant
//! (APFB-GPUBFS-WR-CT-FC) run across K ∈ {1, 2, 4, 8} simulated devices
//! on every generator family. For each cell we report the BSP makespan
//! (max shard clock per level, exchange bottlenecks included), the total
//! modeled work (all shards plus the serial exchange bill), the
//! interconnect traffic the frontier exchange actually routed
//! (`exchange_words` / `exchange_steps`), and the partition's static
//! boundary-edge count — the rows whose neighbor columns straddle a
//! shard cut, i.e. the traffic the column partition *exposes*. The
//! scaling column is makespan(K=1) / makespan(K): where it climbs toward
//! K, sharding pays; where the exchange tax and the replicated phases
//! (INITBFSARRAY, ALTERNATE, FIXMATCHING run mirrored on every device)
//! flatten it, the table shows exactly which term ate the win.
//!
//! Asserts, per family: every K reaches the K=1 cardinality (the sharded
//! driver is one legal serialization of the device race), and K=1 routes
//! no exchange traffic at all (it degenerates to the unsharded bill).
//!
//! Run with: `cargo bench --bench bench_shard` (BIMATCH_SCALE=large for
//! the bigger sizes, BIMATCH_SMOKE=1 for the CI-sized run).

mod common;

use bimatch::gpu::GpuConfig;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::shard::{ColPartition, ShardedGpuMatcher};
use bimatch::util::table::Table;
use bimatch::util::timer::Timer;
use bimatch::MatchingAlgorithm;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

struct ShardRun {
    makespan_ms: f64,
    work_ms: f64,
    exchange_words: u64,
    exchange_steps: u64,
    wall: f64,
    cardinality: usize,
}

fn run_sharded(
    cfg: GpuConfig,
    shards: usize,
    g: &bimatch::graph::BipartiteCsr,
    init: &bimatch::matching::Matching,
) -> ShardRun {
    let t = Timer::start();
    let r = ShardedGpuMatcher::new(cfg, shards).run_detached(g, init.clone());
    let wall = t.elapsed_secs();
    assert_eq!(r.stats.shards, shards as u64);
    ShardRun {
        makespan_ms: r.stats.device_parallel_cycles as f64 / 1e6,
        work_ms: r.stats.device_cycles as f64 / 1e6,
        exchange_words: r.stats.exchange_words,
        exchange_steps: r.stats.exchange_steps,
        wall,
        cardinality: r.matching.cardinality(),
    }
}

fn main() {
    let e = common::env();
    let n = if std::env::var("BIMATCH_SMOKE").is_ok() {
        800
    } else if e.scale.name() == "large" {
        16_000
    } else {
        4_000
    };
    let cfg = GpuConfig::default().compacted(); // shard{K}:gpu:APFB-GPUBFS-WR-CT-FC

    let mut t = Table::new(vec![
        "family",
        "K",
        "|M|",
        "makespan ms",
        "work ms",
        "speedup",
        "exch words",
        "exch steps",
        "boundary edges",
        "wall s",
    ]);
    let mut scaling_cells = 0usize;
    let mut total_multi = 0usize;
    let mut telemetry = common::Report::new("bench_shard");

    for fam in Family::ALL {
        let g = fam.generate(n, 13);
        let init = InitHeuristic::Cheap.run(&g);
        let base = run_sharded(cfg, 1, &g, &init);
        assert_eq!(base.exchange_words, 0, "{}: K=1 cannot move words", fam.name());
        assert_eq!(base.exchange_steps, 0, "{}: K=1 cannot take exchange steps", fam.name());
        for k in SHARD_COUNTS {
            let r = run_sharded(cfg, k, &g, &init);
            assert_eq!(
                base.cardinality,
                r.cardinality,
                "{} at K={k}: sharded cardinality must match K=1",
                fam.name()
            );
            let boundary = ColPartition::new(&g, k).boundary_edge_count(&g);
            if k > 1 {
                total_multi += 1;
                if r.makespan_ms < base.makespan_ms {
                    scaling_cells += 1;
                }
                telemetry.metric(
                    &format!("makespan_speedup.{}@K{k}", fam.name()),
                    base.makespan_ms / r.makespan_ms.max(1e-9),
                    "x",
                    true,
                );
            }
            t.row(vec![
                fam.name().to_string(),
                k.to_string(),
                r.cardinality.to_string(),
                format!("{:.3}", r.makespan_ms),
                format!("{:.3}", r.work_ms),
                format!("{:.2}x", base.makespan_ms / r.makespan_ms.max(1e-9)),
                r.exchange_words.to_string(),
                r.exchange_steps.to_string(),
                boundary.to_string(),
                format!("{:.4}", r.wall),
            ]);
        }
    }

    let mut body = t.render();
    body.push_str(&format!(
        "\nvariant {} across K in {{1,2,4,8}} at n={n}; identical cardinality on every cell.\n\
         makespan is the BSP parallel view (max shard clock per level + exchange\n\
         bottlenecks), work the serial view (all shards + full exchange bill); speedup is\n\
         makespan(K=1)/makespan(K). Multi-shard makespan beat K=1 on {scaling_cells}/{total_multi}\n\
         cells — the flat cells are where exchange traffic (priced per routed (row,col)\n\
         endpoint pair) and the replicated per-device phases eat the partitioned BFS win.\n\
         boundary edges is the static column-partition cut; exch words is what the BFS\n\
         levels actually shipped.",
        cfg.name()
    ));
    common::emit("sharded execution scaling ablation (shard{K}:gpu, 1/2/4/8 devices)", &body);
    telemetry.metric("scaling_cells", scaling_cells as f64, "count", true);
    telemetry.finish();
}
