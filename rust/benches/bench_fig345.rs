//! Figures 3, 4, 5 — the comparative evaluation of the best GPU variant
//! against the multicore baselines and the sequential references:
//!
//! * Fig. 3: log2-scaled speedup profiles w.r.t. the best sequential
//!   algorithm (HK vs PFP per instance), original + permuted sets.
//! * Fig. 4: performance profiles (fraction of instances within x× of the
//!   per-instance best).
//! * Fig. 5: overall geomean speedup of the GPU algorithm w.r.t. PFP and
//!   HK on the four instance sets.
//!
//! Expected shape (paper §4): GPU has the best overall profile; P-DBFS
//! best among multicore on originals, degrading under RCP; P-HK worst.

mod common;

use bimatch::harness::report::{fig3_profiles, fig4_profiles, fig5_overall, win_rate};
use bimatch::util::stats::render_profile_ascii;
use bimatch::util::table::Table;

const GPU: &str = "gpu:APFB-GPUBFS-WR-CT";
const PARALLEL: [&str; 4] = [GPU, "p-dbfs", "p-pfp", "p-hk"];
const SEQ: [&str; 2] = ["hk", "pfp"];

fn main() {
    let mut e = common::env();
    println!("Figures 3/4/5 reproduction (scale={})", e.scale.name());
    let (o_s1, o_hard, r_s1, r_hard) = common::paper_sets(&mut e);

    // measure everything once (cache-backed)
    let mut union_o = o_s1.clone();
    for i in &o_hard {
        if !union_o.contains(i) {
            union_o.push(*i);
        }
    }
    let mut union_r = r_s1.clone();
    for i in &r_hard {
        if !union_r.contains(i) {
            union_r.push(*i);
        }
    }
    let mut algos: Vec<&str> = PARALLEL.to_vec();
    algos.extend(SEQ);
    let mut records = e.evaluator.sweep(&union_o, &algos);
    records.extend(e.evaluator.sweep(&union_r, &algos));

    let xs_log2: Vec<f64> = (-8..=8).map(|i| i as f64 * 0.5).collect();
    for (title, insts) in [("original", common::names(&union_o)), ("permuted", common::names(&union_r))] {
        // ---- Fig. 3 ----
        let profs = fig3_profiles(&records, &PARALLEL, &SEQ, &insts, &xs_log2);
        let mut body = format!("speedup profiles vs best sequential ({title}); x: log2 speedup -4..4\n");
        for (name, pts) in &profs {
            body.push_str(&format!("{name:>22} |{}|\n", render_profile_ascii(pts, 33)));
        }
        // y at x=0 (probability of beating the best sequential)
        for (name, pts) in &profs {
            let at0 = pts.iter().find(|p| p.x == 0.0).map(|p| p.y).unwrap_or(0.0);
            body.push_str(&format!("P({name} >= best-seq) = {:.2}\n", at0));
        }
        common::emit(&format!("Figure 3 ({title})"), &body);

        // ---- Fig. 4 ----
        let xs_perf: Vec<f64> = (1..=40).map(|i| i as f64 * 0.25).collect();
        let profs4 = fig4_profiles(&records, &PARALLEL, &insts, &xs_perf);
        let mut body = format!("performance profiles ({title}); x: within-factor 0.25..10\n");
        for (name, pts) in &profs4 {
            body.push_str(&format!("{name:>22} |{}|\n", render_profile_ascii(pts, 40)));
        }
        for (name, pts) in &profs4 {
            body.push_str(&format!(
                "best-rate({name}) = {:.2}\n",
                pts.first().map(|p| p.y).unwrap_or(0.0)
            ));
        }
        common::emit(&format!("Figure 4 ({title})"), &body);
    }

    // ---- Fig. 5 ----
    let sets = [
        ("O_S1", common::names(&o_s1)),
        ("O_Hardest", common::names(&o_hard)),
        ("RCP_S1", common::names(&r_s1)),
        ("RCP_Hardest", common::names(&r_hard)),
    ];
    let mut t = Table::new(vec!["set", "speedup vs PFP", "speedup vs HK"]);
    for (name, insts) in &sets {
        let overall = fig5_overall(&records, GPU, &["pfp", "hk"], insts);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", overall[0].1),
            format!("{:.2}", overall[1].1),
        ]);
    }
    common::emit("Figure 5 (overall GPU speedup)", &t.render());

    // ---- modeled-GPU view of Fig. 3 / Fig. 5 ----
    // The simulator's host wall-clock measures a *serialized* GPU; for the
    // cross-hardware claim (C2050 vs Xeon) substitute the GPU algorithm's
    // parallel-model device time (gpu::device, PARALLEL_WARPS slots) while
    // keeping the measured wall-clock for every CPU algorithm.
    let modeled: Vec<bimatch::harness::Record> = records
        .iter()
        .map(|r| {
            let mut m = r.clone();
            if m.algo.starts_with("gpu:") {
                m.wall_secs = m.device_parallel_ms / 1e3;
            }
            m
        })
        .collect();
    for (title, insts) in [("original", common::names(&union_o)), ("permuted", common::names(&union_r))] {
        let profs = fig3_profiles(&modeled, &PARALLEL, &SEQ, &insts, &xs_log2);
        let mut body = format!("MODELED speedup profiles vs best sequential ({title})\n");
        for (name, pts) in &profs {
            body.push_str(&format!("{name:>22} |{}|\n", render_profile_ascii(pts, 33)));
        }
        for (name, pts) in &profs {
            let at0 = pts.iter().find(|p| p.x == 0.0).map(|p| p.y).unwrap_or(0.0);
            body.push_str(&format!("P({name} >= best-seq) = {:.2}\n", at0));
        }
        common::emit(&format!("Figure 3 modeled ({title})"), &body);
    }
    let mut t = Table::new(vec!["set", "modeled speedup vs PFP", "modeled speedup vs HK"]);
    for (name, insts) in &sets {
        let overall = fig5_overall(&modeled, GPU, &["pfp", "hk"], insts);
        t.row(vec![
            name.to_string(),
            format!("{:.2}", overall[0].1),
            format!("{:.2}", overall[1].1),
        ]);
    }
    common::emit("Figure 5 modeled (overall GPU speedup)", &t.render());

    // paper §4 headline win-rates
    let body = format!(
        "GPU faster than HK on {:.0}% of originals (paper: 86%)\n\
         GPU faster than PFP on {:.0}% of permuted (paper: 76%)\n",
        win_rate(&modeled, GPU, "hk", &common::names(&union_o)) * 100.0,
        win_rate(&modeled, GPU, "pfp", &common::names(&union_r)) * 100.0,
    );
    common::emit("headline win rates (modeled GPU)", &body);
}
