#![allow(dead_code)]
//! Shared plumbing for the bench binaries (criterion is unavailable
//! offline; each bench is a plain `main` that prints its table/figure and
//! appends a Markdown copy to `target/bimatch_eval/report.md`).

use bimatch::harness::{catalog, Evaluator, Instance, Scale, Subsets};
use std::io::Write;

/// Threshold (seconds) for the "S1" subsets, scaled to this testbed: the
/// paper used 1 s on 2009-era Xeons with million-edge graphs; the small
/// catalog runs ~100× smaller.
pub fn s1_threshold() -> f64 {
    std::env::var("BIMATCH_S1_THRESH")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.010)
}

/// Hardest-K set size (paper: 20 of 70; we keep the same ~30% ratio).
pub fn hardest_k(total: usize) -> usize {
    (total * 2 / 7).max(4)
}

pub struct Env {
    pub scale: Scale,
    pub evaluator: Evaluator,
    pub original: Vec<Instance>,
    pub rcp: Vec<Instance>,
}

pub fn env() -> Env {
    let scale = Scale::from_env();
    let evaluator = Evaluator::new(scale);
    Env {
        scale,
        original: catalog::original(scale),
        rcp: catalog::rcp(scale),
        evaluator,
    }
}

/// Build the paper's four instance sets: (O_S1, O_HardestK, RCP_S1,
/// RCP_HardestK).
pub fn paper_sets(e: &mut Env) -> (Vec<Instance>, Vec<Instance>, Vec<Instance>, Vec<Instance>) {
    let subs_o = Subsets::compute(&mut e.evaluator, &e.original);
    let subs_r = Subsets::compute(&mut e.evaluator, &e.rcp);
    let t = s1_threshold();
    let k_o = hardest_k(e.original.len());
    let k_r = hardest_k(e.rcp.len());
    (
        subs_o.s1(&e.original, t),
        subs_o.hardest(&e.original, k_o),
        subs_r.s1(&e.rcp, t),
        subs_r.hardest(&e.rcp, k_r),
    )
}

#[allow(dead_code)]
pub fn names(instances: &[Instance]) -> Vec<String> {
    instances.iter().map(|i| i.name()).collect()
}

/// Print to stdout and append to the markdown report.
pub fn emit(section: &str, body: &str) {
    println!("{body}");
    let _ = std::fs::create_dir_all("target/bimatch_eval");
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("target/bimatch_eval/report.md")
    {
        let _ = writeln!(f, "\n## {section}\n\n```\n{body}\n```");
    }
}

/// Machine-readable bench telemetry (schema `bimatch-bench/1`): each
/// bench collects named metrics and [`Report::finish`] writes
/// `target/bench/<bench>.json` — the input `bimatch bench-report`
/// merges and gates against the committed baseline.
pub struct Report {
    bench: &'static str,
    metrics: Vec<(String, f64, &'static str, bool)>,
}

impl Report {
    pub fn new(bench: &'static str) -> Self {
        Self { bench, metrics: Vec::new() }
    }

    /// Record one metric. `higher_is_better` drives the regression gate's
    /// direction (ops/sec: true; seconds or bytes: false).
    pub fn metric(&mut self, name: &str, value: f64, unit: &'static str, higher_is_better: bool) {
        self.metrics.push((name.to_string(), value, unit, higher_is_better));
    }

    /// Write `target/bench/<bench>.json`. Hand-rolled JSON (serde is
    /// unavailable offline); metric names are bench-chosen identifiers
    /// and units are static strings, so only escaping-free content lands
    /// here by construction — asserted, not assumed.
    pub fn finish(self) {
        let smoke = std::env::var("BIMATCH_SMOKE").is_ok();
        let git = option_env!("BIMATCH_GIT_HASH").unwrap_or("unknown");
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut body = String::new();
        for (name, value, unit, hib) in &self.metrics {
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || "_-./@:".contains(c)),
                "metric name {name:?} needs JSON escaping"
            );
            if !body.is_empty() {
                body.push(',');
            }
            let rendered = if value.fract() == 0.0 && value.abs() < 9.0e15 {
                format!("{}", *value as i64)
            } else {
                format!("{value:.6}")
            };
            body.push_str(&format!(
                "{{\"name\":\"{name}\",\"value\":{rendered},\"unit\":\"{unit}\",\
                 \"higher_is_better\":{hib}}}"
            ));
        }
        let doc = format!(
            "{{\"schema\":\"bimatch-bench/1\",\"bench\":\"{}\",\"unix_ms\":{unix_ms},\
             \"smoke\":{smoke},\"git\":\"{git}\",\"metrics\":[{body}]}}\n",
            self.bench
        );
        let _ = std::fs::create_dir_all("target/bench");
        let path = format!("target/bench/{}.json", self.bench);
        if let Err(e) = std::fs::write(&path, doc) {
            eprintln!("bench telemetry write {path} failed: {e}");
        } else {
            println!("telemetry: {path}");
        }
    }
}
