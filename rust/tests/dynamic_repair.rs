//! The incremental subsystem's acceptance property: for every generator
//! family and every update batch, `repair()` lands on exactly the
//! cardinality a from-scratch run computes on the mutated graph — on the
//! CPU path and the GPU path, under FullScan and Compacted frontiers —
//! and the repaired matching certifies (valid + maximum, Berge).

use bimatch::coordinator::registry;
use bimatch::coordinator::spec::AlgoSpec;
use bimatch::dynamic::{repair, DeltaBatch, DynamicGraph};
use bimatch::graph::csr::BipartiteCsr;
use bimatch::graph::from_edges;
use bimatch::graph::gen::Family;
use bimatch::matching::{reference_max_cardinality, Matching};
use bimatch::util::qcheck::{arb_bipartite, forall, Config};
use bimatch::util::rng::Xoshiro256;
use bimatch::{MatchingAlgorithm, RunCtx};

/// The four repair backends the acceptance criterion names: CPU, and GPU
/// in both frontier modes (plus the APsB/improved-WR driver under
/// compaction, whose endpoint encoding is the trickiest seeded path).
fn repair_specs() -> Vec<AlgoSpec> {
    ["pfp", "gpu:APFB-GPUBFS-WR-CT", "gpu:APFB-GPUBFS-WR-CT-FC", "gpu:APsB-GPUBFS-WR-CT-FC"]
        .into_iter()
        .map(|s| s.parse().unwrap())
        .collect()
}

fn solve(g: &BipartiteCsr) -> Matching {
    let algo = registry::build_named("hk", None).unwrap();
    let m = algo.run_detached(g, Matching::empty(g.nr, g.nc)).matching;
    m.certify(g).unwrap();
    m
}

/// A random batch biased toward the interesting cases: deleting *matched*
/// edges (exposes vertices), deleting arbitrary edges, inserting random
/// pairs (duplicates become rejected no-ops), and appending columns.
fn random_batch(rng: &mut Xoshiro256, g: &BipartiteCsr, m: &Matching, ops: usize) -> DeltaBatch {
    let edges = g.edges();
    let mut b = DeltaBatch::new();
    for _ in 0..ops {
        match rng.gen_range(5) {
            0 | 1 => {
                let matched: Vec<usize> = (0..g.nc).filter(|&c| m.cmatch[c] >= 0).collect();
                if !matched.is_empty() {
                    let c = matched[rng.gen_range(matched.len())];
                    b = b.delete(m.cmatch[c] as u32, c as u32);
                }
            }
            2 => {
                if !edges.is_empty() {
                    let (r, c) = edges[rng.gen_range(edges.len())];
                    b = b.delete(r, c);
                }
            }
            3 => {
                let r = rng.gen_range(g.nr) as u32;
                let c = rng.gen_range(g.nc) as u32;
                b = b.insert(r, c);
            }
            _ => {
                let k = rng.gen_range(3);
                let rows: Vec<u32> = (0..k).map(|_| rng.gen_range(g.nr) as u32).collect();
                b = b.add_column(rows);
            }
        }
    }
    b
}

/// Apply `batch`, then check every backend repairs `prev` to the
/// reference cardinality of the mutated graph. Returns the mutated graph
/// and one repaired matching to continue a maintained chain with.
fn check_batch(
    dg: &mut DynamicGraph,
    prev: &Matching,
    batch: &DeltaBatch,
    label: &str,
) -> (std::sync::Arc<BipartiteCsr>, Matching) {
    let report = dg.apply(batch);
    let g = dg.snapshot();
    let want = reference_max_cardinality(&g);
    let mut keep = None;
    for spec in repair_specs() {
        let s = repair(&g, prev.clone(), &report, &spec, None, &mut RunCtx::detached())
            .unwrap_or_else(|e| panic!("{label} / {spec}: repair failed: {e}"));
        s.result
            .matching
            .certify(&g)
            .unwrap_or_else(|e| panic!("{label} / {spec}: {e}"));
        assert_eq!(
            s.result.matching.cardinality(),
            want,
            "{label} / {spec}: repair != from-scratch reference"
        );
        assert!(
            s.start_cardinality <= s.result.matching.cardinality(),
            "{label} / {spec}: repair may only grow the matching"
        );
        keep = Some(s.result.matching);
    }
    (g, keep.expect("at least one spec ran"))
}

#[test]
fn repair_equals_recompute_on_every_family() {
    // every generator family × a maintained chain of update batches
    for (i, fam) in Family::ALL.iter().enumerate() {
        let base = fam.generate(240, 7 + i as u64);
        let mut maintained = solve(&base);
        let mut dg = DynamicGraph::new(base);
        let mut rng = Xoshiro256::new(0xD17A_0000 + i as u64);
        for round in 0..3 {
            let g_before = dg.snapshot();
            let batch = random_batch(&mut rng, &g_before, &maintained, 8);
            let label = format!("{} round {round}", fam.name());
            let (_, repaired) = check_batch(&mut dg, &maintained, &batch, &label);
            maintained = repaired;
        }
    }
}

#[test]
fn prop_repair_equals_recompute_on_random_graphs() {
    forall(Config::cases(16), |rng| {
        let (nr, nc, edges) = arb_bipartite(rng, 20);
        let base = from_edges(nr, nc, &edges);
        let prev = solve(&base);
        let mut dg = DynamicGraph::new(base);
        let g0 = dg.snapshot();
        let batch = random_batch(rng, &g0, &prev, 6);
        let report = dg.apply(&batch);
        let g = dg.snapshot();
        let want = reference_max_cardinality(&g);
        for spec in repair_specs() {
            let s = repair(&g, prev.clone(), &report, &spec, None, &mut RunCtx::detached())
                .map_err(|e| format!("{spec}: {e}"))?;
            s.result.matching.certify(&g).map_err(|e| format!("{spec}: {e}"))?;
            if s.result.matching.cardinality() != want {
                return Err(format!(
                    "{spec}: repaired {} != reference {want} (batch {batch:?})",
                    s.result.matching.cardinality()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn repair_survives_deleting_every_matched_edge() {
    // the worst batch: sever the entire matching — repair degenerates to
    // (seeded) recompute and must still land on the reference
    let base = Family::Kron.generate(300, 5);
    let prev = solve(&base);
    let mut batch = DeltaBatch::new();
    for c in 0..base.nc {
        if prev.cmatch[c] >= 0 {
            batch = batch.delete(prev.cmatch[c] as u32, c as u32);
        }
    }
    let mut dg = DynamicGraph::new(base);
    check_batch(&mut dg, &prev, &batch, "sever-all");
}

#[test]
fn repair_chain_through_rebuilds_stays_consistent() {
    // force aggressive overlay compaction: the rebuild must be invisible
    // to repair correctness
    let base = Family::Road.generate(300, 11);
    let mut maintained = solve(&base);
    let mut dg = DynamicGraph::new(base).with_rebuild_threshold(0.0);
    let mut rng = Xoshiro256::new(0xBEEF);
    for round in 0..4 {
        let g_before = dg.snapshot();
        let batch = random_batch(&mut rng, &g_before, &maintained, 5);
        let label = format!("rebuild round {round}");
        let (_, repaired) = check_batch(&mut dg, &maintained, &batch, &label);
        maintained = repaired;
    }
    assert!(dg.rebuilds() > 0, "threshold 0 must have forced rebuilds");
}
