//! End-to-end exercises for the correctness analyzers (`sanitize`):
//!
//! * negative proof — an intentionally racy kernel (plain same-cell
//!   writes from distinct modeled threads) is flagged, and an atomic RMW
//!   without its `CAS_COST` charge is flagged;
//! * positive proof — every registry GPU variant (both frontier modes)
//!   runs sanitizer-clean at device parallelism 1 and 4 across three
//!   generator families, with the paper's *sanctioned* races routed
//!   through the atomic substrate;
//! * the lock-order watchdog turns a manufactured inversion into a
//!   deterministic panic (debug builds).
//!
//! The racy kernels here run with `nthreads = 1`: detection keys on
//! *modeled* thread identity, not host interleaving, so the negative
//! tests are deterministic and free of real undefined behavior.

use bimatch::coordinator::registry;
use bimatch::gpu::device::{self, DeviceClock, CAS_COST, ITEM_COST};
use bimatch::gpu::{GpuConfig, GpuMatcher, ThreadMapping};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::sanitize::race;
use bimatch::util::pool::{AtomicCells, SharedSlice};
use bimatch::MatchingAlgorithm;

#[test]
#[should_panic(expected = "non-atomic write/write")]
fn adversarial_plain_write_race_is_flagged() {
    let _on = race::ScopedEnable::new();
    let mut clock = DeviceClock::default();
    let mut data = vec![0i32; 8];
    let s = SharedSlice::new(&mut data);
    let mut work = Vec::new();
    // every modeled item writes cell 0 without going through AtomicCells —
    // exactly the bug class the paper's atomic-free kernels must not have
    device::launch_parallel_racy(
        &mut clock,
        ThreadMapping::Ct,
        "ADVERSARIAL-WW",
        8,
        1,
        &mut work,
        |item, _lane| {
            // SAFETY: single host thread (nthreads = 1), so the raw writes
            // cannot be a real data race — only a modeled one.
            unsafe { s.set(0, item as i32) };
            ITEM_COST
        },
    );
}

#[test]
#[should_panic(expected = "undercharged")]
fn atomic_rmw_without_cas_cost_is_flagged() {
    let _on = race::ScopedEnable::new();
    let mut clock = DeviceClock::default();
    let mut data = vec![0i32; 8];
    let cells = AtomicCells::new(&mut data);
    let mut work = Vec::new();
    device::launch_parallel_racy(
        &mut clock,
        ThreadMapping::Ct,
        "ADVERSARIAL-FREECAS",
        4,
        1,
        &mut work,
        |item, _lane| {
            cells.cas(item, 0, 1);
            0 // an RMW happened but no CAS_COST was charged
        },
    );
}

#[test]
fn sanctioned_atomic_race_is_clean() {
    let _on = race::ScopedEnable::new();
    let mut clock = DeviceClock::default();
    let mut data = vec![0i32; 4];
    let cells = AtomicCells::new(&mut data);
    let mut work = Vec::new();
    // same single-cell contention as the flagged kernel above, but routed
    // through the atomic substrate and paid for — the paper's model of a
    // benign race ("any winner is fine"), and the sanitizer stays quiet
    device::launch_parallel_racy(
        &mut clock,
        ThreadMapping::Ct,
        "SANCTIONED",
        8,
        1,
        &mut work,
        |item, _lane| {
            cells.swap(0, item as i32);
            CAS_COST
        },
    );
    assert!(clock.cycles > 0);
    assert!((0..8).contains(&(data[0] as usize)), "some writer won");
}

/// Every registry GPU variant — APFB/APsB × GPUBFS/GPUBFS-WR × CT/MT,
/// each in FullScan and Compacted frontier mode — must run sanitizer-clean
/// at device parallelism 1 and 4, on three generator families, and still
/// produce a certified maximum of the reference cardinality.
#[test]
fn registry_kernels_are_sanitizer_clean_across_variants_and_parallelism() {
    let _on = race::ScopedEnable::new();
    let reference = registry::build_named("hk", None).unwrap();
    for family in ["uniform", "banded", "kron"] {
        let g = Family::from_name(family).unwrap().generate(400, 7);
        let init = InitHeuristic::Cheap.run(&g);
        let want = {
            let r = reference.run_detached(&g, init.clone());
            r.matching.certify(&g).unwrap();
            r.matching.cardinality()
        };
        for base in GpuConfig::all_variants_with_frontier() {
            for par in [1usize, 4] {
                let cfg = GpuConfig { device_parallelism: par, ..base };
                let name = cfg.name();
                let r = GpuMatcher::new(cfg).run_detached(&g, init.clone());
                r.matching
                    .certify(&g)
                    .unwrap_or_else(|e| panic!("{name}@par{par} on {family}: {e}"));
                assert_eq!(
                    r.matching.cardinality(),
                    want,
                    "{name}@par{par} on {family}: cardinality drifted"
                );
            }
        }
    }
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "lock-order violation")]
fn watchdog_flags_manufactured_inversion() {
    use bimatch::sanitize::lockorder::{lock, LockClass};
    use std::sync::Mutex;
    let a = Mutex::new(());
    let b = Mutex::new(());
    {
        // establish TestA → TestB
        let _ga = lock(LockClass::TestA, &a);
        let _gb = lock(LockClass::TestB, &b);
    }
    // ... then attempt the inversion: this acquisition must panic even
    // though no other thread is anywhere near these locks
    let _gb = lock(LockClass::TestB, &b);
    let _ga = lock(LockClass::TestA, &a);
}
