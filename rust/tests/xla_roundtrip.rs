//! Rust↔XLA round-trip integration: load the AOT artifacts through the
//! PJRT engine and verify the L1/L2 programs agree with the native Rust
//! algorithms. Requires `make artifacts` (tests self-skip with a clear
//! message when artifacts are absent — CI runs them after the Makefile
//! target).

use bimatch::gpu::xla_backend::{XlaApfbMatcher, XlaHybridMatcher};
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::{reference_max_cardinality, Matching};
use bimatch::runtime::{ArtifactKind, Engine};
use bimatch::MatchingAlgorithm;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    match Engine::open_default() {
        Ok(e) => Some(Arc::new(e)),
        Err(err) => {
            eprintln!("SKIP (run `make artifacts`): {err:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_both_kinds_per_bucket() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    assert!(!m.buckets().is_empty());
    for (nc, nr, k) in m.buckets() {
        assert!(m.find_bucket(ArtifactKind::BfsLevel, nc, nr, k).is_some());
        assert!(m.find_bucket(ArtifactKind::ApfbFull, nc, nr, k).is_some());
    }
}

#[test]
fn all_artifacts_compile() {
    let Some(engine) = engine() else { return };
    let names: Vec<String> = engine.manifest().artifacts.iter().map(|a| a.name.clone()).collect();
    for name in names {
        let exe = engine.load(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(exe.meta.name, name);
    }
}

#[test]
fn executable_cache_returns_same_instance() {
    let Some(engine) = engine() else { return };
    let name = &engine.manifest().artifacts[0].name.clone();
    let a = engine.load(name).unwrap();
    let b = engine.load(name).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "second load must hit the cache");
}

#[test]
fn xla_apfb_matches_reference_on_families() {
    let Some(engine) = engine() else { return };
    let matcher = XlaApfbMatcher::new(engine);
    for family in [Family::Uniform, Family::Road, Family::Banded] {
        let g = family.generate(900, 21);
        if g.nc > 1024 || g.nr > 1024 || g.max_col_degree() > 8 {
            // uniform/road/banded at n=900 fit the small bucket; guard
            // against generator drift
            continue;
        }
        let init = InitHeuristic::Cheap.run(&g);
        let r = matcher.try_run(&g, &init).unwrap();
        r.matching.certify(&g).unwrap_or_else(|e| panic!("{}: {e}", family.name()));
        assert_eq!(
            r.matching.cardinality(),
            reference_max_cardinality(&g),
            "{}",
            family.name()
        );
        assert!(r.stats.phases >= 1);
    }
}

#[test]
fn xla_apfb_from_empty_init() {
    let Some(engine) = engine() else { return };
    let matcher = XlaApfbMatcher::new(engine);
    let g = Family::Uniform.generate(800, 5);
    let r = matcher.try_run(&g, &Matching::empty(g.nr, g.nc)).unwrap();
    r.matching.certify(&g).unwrap();
    assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
}

#[test]
fn xla_hybrid_matches_native() {
    let Some(engine) = engine() else { return };
    let hybrid = XlaHybridMatcher::new(engine);
    let g = Family::Uniform.generate(700, 13);
    let init = InitHeuristic::Cheap.run(&g);
    let r = hybrid.try_run(&g, &init).unwrap();
    r.matching.certify(&g).unwrap();
    assert_eq!(r.matching.cardinality(), reference_max_cardinality(&g));
    assert!(r.stats.bfs_kernel_launches >= r.stats.phases);
}

#[test]
fn oversized_graph_rejected_cleanly() {
    let Some(engine) = engine() else { return };
    let matcher = XlaApfbMatcher::new(engine);
    // 9000 > the biggest default bucket (4096)
    let g = Family::Uniform.generate(9000, 1);
    let err = matcher.try_run(&g, &Matching::empty(g.nr, g.nc));
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("artifact"), "{msg}");
}

#[test]
fn registry_builds_xla_matchers_with_engine() {
    let Some(engine) = engine() else { return };
    let g = Family::Uniform.generate(600, 7);
    let init = InitHeuristic::Cheap.run(&g);
    for name in ["xla:apfb-full", "xla:bfs-level-hybrid"] {
        let algo =
            bimatch::coordinator::registry::build_named(name, Some(engine.clone())).unwrap();
        let r = algo.run_detached(&g, init.clone());
        r.matching.certify(&g).unwrap();
        assert_eq!(r.stats.fallbacks, 0, "{name} must not fall back with artifacts present");
    }
}
