//! Durability acceptance: (1) the end-to-end proof that a service can be
//! dropped and a fresh one recovers every stored graph from `--data-dir`
//! by *repairing* (not recomputing) its matching, and (2) the
//! crash-consistency property — for random LOAD/UPDATE/SAVE/DROP
//! histories, truncating the write-ahead log at **every byte boundary of
//! its final frame** recovers a prefix-consistent store whose restored
//! matchings equal the from-scratch reference cardinality.

use bimatch::coordinator::job::{GraphSource, MatchJob};
use bimatch::coordinator::{registry, router, Executor, Metrics, Service, ServiceConfig};
use bimatch::dynamic::DeltaBatch;
use bimatch::graph::csr::BipartiteCsr;
use bimatch::graph::from_edges;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::reference_max_cardinality;
use bimatch::persist::Persistence;
use bimatch::util::qcheck::{arb_bipartite, forall, Config};
use bimatch::util::rng::Xoshiro256;
use bimatch::MatchingAlgorithm;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bimatch_recovery_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sorted_edges(g: &BipartiteCsr) -> Vec<(u32, u32)> {
    let mut e = g.edges();
    e.sort_unstable();
    e
}

/// The e2e durability proof from the issue's acceptance criteria: LOAD a
/// graph, apply three UPDATE batches (the middle one big enough to force
/// the threshold CSR rebuild, which piggybacks a snapshot), drop the
/// `Service`, recover from `--data-dir` into a fresh `Service`, and
/// `MATCH name=` returns the identical cardinality — with
/// `graphs_recovered ≥ 1` and the recovery completing via *seeded
/// repair*: strictly fewer phases than a cold recompute on the same
/// graph (asserted via `RunStats`).
#[test]
fn end_to_end_durability_proof() {
    let dir = temp_dir("e2e");
    let n = 5000usize;
    // the generator is deterministic, so the test knows the exact graph
    // the server holds and can name real edges / real non-edges
    let g0 = Family::Uniform.generate(n, 42);
    let edges = g0.edges();
    let mut non_edges = Vec::new();
    'scan: for r in 0..g0.nr as u32 {
        for c in 0..g0.nc as u32 {
            if !g0.has_edge(r as usize, c as usize) {
                non_edges.push((r, c));
                if non_edges.len() > g0.n_edges() {
                    break 'scan;
                }
            }
        }
    }
    // batch 1: ordinary churn — deletions, an insertion, a column, a row
    let batch1 = DeltaBatch::new()
        .delete(edges[0].0, edges[0].1)
        .delete(edges[100].0, edges[100].1)
        .insert(non_edges[0].0, non_edges[0].1)
        .add_column(vec![0, 1, 2])
        .add_row(vec![3, 4]);
    // batch 2: > 25% of the base edges — forces the rebuild + snapshot
    let mut batch2 = DeltaBatch::new();
    let need = g0.n_edges() / 3;
    for &(r, c) in non_edges.iter().skip(1).take(need) {
        batch2 = batch2.insert(r, c);
    }
    // batch 3: small tail that lives only in the WAL after the snapshot
    let batch3 = DeltaBatch::new()
        .delete(edges[7].0, edges[7].1)
        .insert(non_edges[need + 1].0, non_edges[need + 1].1);

    let svc = Service::start_cfg(ServiceConfig::new(1, 16).data_dir(&dir)).unwrap();
    let jobs = vec![
        MatchJob::load_graph(0, "g", GraphSource::InMemory(Arc::new(g0.clone()))),
        MatchJob::new(1, GraphSource::Stored("g".into())),
        MatchJob::update_graph(2, "g", batch1),
        MatchJob::update_graph(3, "g", batch2),
        MatchJob::update_graph(4, "g", batch3),
        MatchJob::new(5, GraphSource::Stored("g".into())),
    ];
    let (outcomes, _) = svc.run_batch(jobs);
    for o in &outcomes {
        assert!(o.error.is_none(), "job {}: {:?}", o.job_id, o.error);
    }
    assert!(
        outcomes[3].update.expect("update stats").rebuilt,
        "the big batch must trip the threshold rebuild (and its snapshot)"
    );
    let final_card = outcomes[5].cardinality;
    assert!(outcomes[5].certified);
    // the service is gone; everything below comes from the data dir

    let svc2 = Service::start_cfg(ServiceConfig::new(1, 16).data_dir(&dir)).unwrap();
    let report = svc2.recovery().expect("durable start must report recovery").clone();
    assert_eq!(report.recovered(), 1, "skipped: {:?}", report.skipped);
    assert!(svc2.metrics.graphs_recovered.load(Ordering::Relaxed) >= 1);
    let gr = &report.graphs[0];
    assert_eq!(gr.name, "g");
    assert!(gr.clean, "a cleanly shut down log must replay fully");
    assert_eq!(
        gr.replayed_updates, 1,
        "the rebuild snapshot covers batches 1-2; only batch 3 replays"
    );
    assert_eq!(gr.cardinality, Some(final_card), "recovery must restore the matching");
    let repair_phases = gr.repair_phases.expect("recovery must repair, not recompute");

    // cold recompute on the identical graph with the identical routed
    // spec: the recovery's seeded repair must close in strictly fewer
    // phases — that is the whole point of persisting deltas + matching
    let live = svc2.store().graph_for_match("g").unwrap().graph;
    let spec = router::route_graph(&live);
    let algo = registry::build(&spec, None).unwrap();
    let cold = algo.run_detached(&live, InitHeuristic::Cheap.run(&live));
    assert_eq!(cold.matching.cardinality(), final_card, "sanity: same graph");
    assert!(
        repair_phases < cold.stats.phases,
        "recovery repair took {repair_phases} phases, cold recompute {} — \
         recovery must be the cheaper seeded path",
        cold.stats.phases
    );

    // and the recovered service serves the identical answer, warm
    let (outcomes, metrics) =
        svc2.run_batch(vec![MatchJob::new(9, GraphSource::Stored("g".into()))]);
    assert!(outcomes[0].certified, "{:?}", outcomes[0].error);
    assert_eq!(outcomes[0].cardinality, final_card);
    assert_eq!(
        outcomes[0].init_cardinality, final_card,
        "the recovered matching must warm-start the first MATCH"
    );
    assert!(metrics.graphs_recovered.load(Ordering::Relaxed) >= 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The sharded-persistence half of the proof: with `snapshot_shards=4`
/// every snapshot lands as a set of per-shard member files under the one
/// per-graph WAL, and a fresh service — even one configured for the
/// single-file layout — recovers the identical graph and matching from
/// the assembled set.
#[test]
fn sharded_snapshots_survive_a_service_restart() {
    let dir = temp_dir("shard_e2e");
    let g0 = Family::Kron.generate(2000, 7);
    let mut non_edges = Vec::new();
    'scan: for r in 0..g0.nr as u32 {
        for c in 0..g0.nc as u32 {
            if !g0.has_edge(r as usize, c as usize) {
                non_edges.push((r, c));
                if non_edges.len() >= 8 {
                    break 'scan;
                }
            }
        }
    }
    let batch = DeltaBatch::new().insert(non_edges[0].0, non_edges[0].1).add_column(vec![1, 2]);

    let svc =
        Service::start_cfg(ServiceConfig::new(1, 16).data_dir(&dir).snapshot_shards(4))
            .unwrap();
    let jobs = vec![
        MatchJob::load_graph(0, "g", GraphSource::InMemory(Arc::new(g0.clone()))),
        MatchJob::new(1, GraphSource::Stored("g".into())),
        MatchJob::update_graph(2, "g", batch),
        MatchJob::save_graph(3, "g"),
        MatchJob::new(4, GraphSource::Stored("g".into())),
    ];
    let (outcomes, _) = svc.run_batch(jobs);
    for o in &outcomes {
        assert!(o.error.is_none(), "job {}: {:?}", o.job_id, o.error);
    }
    let final_card = outcomes[4].cardinality;
    drop(svc);

    // the data dir holds shard members, not single-file snapshots
    let entries: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok()?.file_name().into_string().ok())
        .collect();
    assert!(
        entries.iter().filter(|f| f.contains(".s") && f.ends_with(".snap")).count() >= 4,
        "expected per-shard members in {entries:?}"
    );

    // recover with the default (single-file) config: read paths must
    // accept the sharded layout regardless of the writer knob
    let svc2 = Service::start_cfg(ServiceConfig::new(1, 16).data_dir(&dir)).unwrap();
    let report = svc2.recovery().expect("durable start must report recovery").clone();
    assert_eq!(report.recovered(), 1, "skipped: {:?}", report.skipped);
    assert_eq!(report.graphs[0].cardinality, Some(final_card));
    let (outcomes, _) = svc2.run_batch(vec![MatchJob::new(9, GraphSource::Stored("g".into()))]);
    assert!(outcomes[0].certified, "{:?}", outcomes[0].error);
    assert_eq!(outcomes[0].cardinality, final_card);
    let _ = std::fs::remove_dir_all(&dir);
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

/// Byte offsets of each well-formed frame in a WAL we wrote ourselves.
fn frame_starts(bytes: &[u8]) -> Vec<usize> {
    let mut starts = Vec::new();
    let mut at = 0usize;
    while at + 13 <= bytes.len() {
        let len =
            u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
        let end = at + 4 + 1 + len + 8;
        if end > bytes.len() {
            break;
        }
        starts.push(at);
        at = end;
    }
    starts
}

/// A random non-empty-ish batch over the live graph: deletions of real
/// edges, insertions of random pairs, column and row additions.
fn random_batch(rng: &mut Xoshiro256, g: &BipartiteCsr) -> DeltaBatch {
    let edges = g.edges();
    let mut b = DeltaBatch::new();
    for _ in 0..(1 + rng.gen_range(5)) {
        match rng.gen_range(6) {
            0 | 1 if !edges.is_empty() => {
                let (r, c) = edges[rng.gen_range(edges.len())];
                b = b.delete(r, c);
            }
            2 | 3 => {
                b = b.insert(rng.gen_range(g.nr) as u32, rng.gen_range(g.nc) as u32);
            }
            4 => {
                let k = rng.gen_range(3);
                b = b.add_column((0..k).map(|_| rng.gen_range(g.nr) as u32).collect());
            }
            _ => {
                let k = rng.gen_range(3);
                b = b.add_row((0..k).map(|_| rng.gen_range(g.nc) as u32).collect());
            }
        }
    }
    b
}

/// Shape + edge set: what "the same graph state" means below (an
/// isolated appended column/row changes nr/nc without touching edges).
type GraphState = (usize, usize, Vec<(u32, u32)>);

fn state_of(g: &BipartiteCsr) -> GraphState {
    (g.nr, g.nc, sorted_edges(g))
}

fn load_random(
    e: &Executor,
    rng: &mut Xoshiro256,
    states: &mut Vec<GraphState>,
    id: u64,
) -> Result<(), String> {
    let (nr, nc, edges) = arb_bipartite(rng, 9);
    let g = from_edges(nr, nc, &edges);
    let out =
        e.execute(&MatchJob::load_graph(id, "g", GraphSource::InMemory(Arc::new(g.clone()))));
    if let Some(err) = out.error {
        return Err(format!("LOAD failed: {err}"));
    }
    states.clear();
    states.push(state_of(&g));
    Ok(())
}

/// Recover `dir` into a fresh executor and compare graph "g" against the
/// expected state; whenever a matching was restored, check repair ≡
/// recompute against the from-scratch reference.
fn check_recovered(dir: &Path, want: &GraphState, label: &str) -> Result<(), String> {
    let e2 = Executor::new(None, Arc::new(Metrics::new()))
        .with_persistence(Arc::new(Persistence::open(dir).map_err(|e| e.to_string())?));
    e2.recover().map_err(|e| e.to_string())?;
    let Some(view) = e2.store().graph_for_match("g") else {
        return Err(format!("{label}: graph did not recover"));
    };
    let got = state_of(&view.graph);
    if got != *want {
        return Err(format!("{label}: recovered state {got:?} != expected {want:?}"));
    }
    if let Some(cached) = view.cached {
        let want_card = reference_max_cardinality(&view.graph);
        if cached.matching.cardinality() != want_card {
            return Err(format!(
                "{label}: restored matching has cardinality {}, reference {}",
                cached.matching.cardinality(),
                want_card
            ));
        }
    }
    Ok(())
}

/// The crash-consistency property. For random LOAD/UPDATE/SAVE/DROP
/// histories over one name:
///
/// * recovery of the intact dir reproduces the exact final committed
///   state (shape and edge set);
/// * truncating the WAL at *every byte boundary inside its final frame*
///   recovers exactly the state before the final committed update
///   (prefix consistency: an acknowledged update is wholly present or
///   wholly absent, never partial);
/// * whenever a matching is restored, its cardinality equals the
///   from-scratch reference on the recovered graph (`repair ≡
///   recompute`).
#[test]
fn truncated_wal_recovery_is_prefix_consistent() {
    forall(Config::cases(5).with_seed(0xD0C5), |rng| {
        let tag = rng.next_u64();
        let dir = temp_dir(&format!("prop_{tag:016x}"));
        let p = Arc::new(Persistence::open(&dir).map_err(|e| e.to_string())?);
        let e = Executor::new(None, Arc::new(Metrics::new())).with_persistence(p.clone());
        let mut id = 0u64;
        // committed states of the CURRENT incarnation of "g": one entry
        // per state change (LOAD, then each non-noop UPDATE)
        let mut states: Vec<GraphState> = Vec::new();
        let mut alive = false;
        let n_ops = 5 + rng.gen_range(5);
        for _ in 0..n_ops {
            id += 1;
            let roll = rng.gen_range(12);
            if !alive || roll == 0 {
                load_random(&e, rng, &mut states, id)?;
                alive = true;
            } else if roll == 1 {
                let out = e.execute(&MatchJob::drop_graph(id, "g"));
                if let Some(err) = out.error {
                    return Err(format!("DROP failed: {err}"));
                }
                states.clear();
                alive = false;
            } else if roll == 2 {
                let out = e.execute(&MatchJob::save_graph(id, "g"));
                if let Some(err) = out.error {
                    return Err(format!("SAVE failed: {err}"));
                }
            } else {
                let live_g = e.store().graph_for_match("g").unwrap().graph;
                let batch = random_batch(rng, &live_g);
                let out = e.execute(&MatchJob::update_graph(id, "g", batch));
                if let Some(err) = out.error {
                    return Err(format!("UPDATE failed: {err}"));
                }
                let u = out.update.expect("update stats");
                if u.inserted + u.deleted + u.cols_added + u.rows_added > 0 {
                    let now = e.store().graph_for_match("g").unwrap().graph;
                    states.push(state_of(&now));
                }
            }
        }
        // the history must end alive with one guaranteed-structural
        // update, so there is a final committed state to truncate away
        if !alive {
            id += 1;
            load_random(&e, rng, &mut states, id)?;
        }
        id += 1;
        let out =
            e.execute(&MatchJob::update_graph(id, "g", DeltaBatch::new().add_column(vec![])));
        if let Some(err) = out.error {
            return Err(format!("final UPDATE failed: {err}"));
        }
        let now = e.store().graph_for_match("g").unwrap().graph;
        states.push(state_of(&now));

        // full recovery reproduces the exact final state
        check_recovered(&dir, states.last().unwrap(), "intact dir")?;

        // the final WAL frame is the final committed update (the
        // guaranteed add_column — nothing snapshotted after it); cut it
        // at every byte boundary
        let wal_path = p.wal_path("g");
        let wal_name = wal_path.file_name().unwrap().to_owned();
        let wal_bytes = std::fs::read(&wal_path).map_err(|e| e.to_string())?;
        let starts = frame_starts(&wal_bytes);
        let last_start = *starts.last().ok_or("WAL unexpectedly empty")?;
        let before_last = states[states.len() - 2].clone();
        for cut in last_start..wal_bytes.len() {
            let dir2 = temp_dir(&format!("prop_{tag:016x}_cut"));
            copy_dir(&dir, &dir2);
            std::fs::write(dir2.join(&wal_name), &wal_bytes[..cut])
                .map_err(|e| e.to_string())?;
            check_recovered(&dir2, &before_last, &format!("cut at byte {cut}"))?;
            let _ = std::fs::remove_dir_all(&dir2);
        }
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}
