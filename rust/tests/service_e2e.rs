//! Coordinator integration: service batches, routing behaviour, failure
//! injection, and the TCP server against a live socket.

use bimatch::coordinator::job::{GraphSource, JobError, MatchJob};
use bimatch::coordinator::{Server, Service};
use bimatch::graph::gen::Family;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn gen_job(id: u64, family: Family, n: usize, permute: bool) -> MatchJob {
    MatchJob::new(id, GraphSource::Generate { family, n, seed: id + 1, permute })
}

#[test]
fn service_runs_mixed_trace_certified() {
    let svc = Service::start(2, 8, None);
    let mut jobs = Vec::new();
    for (i, family) in Family::ALL.iter().enumerate() {
        jobs.push(gen_job(i as u64, *family, 600, i % 2 == 0));
    }
    let (outcomes, metrics) = svc.run_batch(jobs);
    assert_eq!(outcomes.len(), Family::ALL.len());
    for o in &outcomes {
        assert!(o.error.is_none(), "{:?}", o.error);
        assert!(o.certified);
        assert!(o.cardinality >= o.init_cardinality);
    }
    assert_eq!(metrics.completed(), Family::ALL.len() as u64);
    assert_eq!(metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(
        metrics.jobs_submitted.load(std::sync::atomic::Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed)
    );
}

#[test]
fn router_sends_banded_to_pfp_and_permuted_to_gpu() {
    let svc = Service::start(1, 4, None);
    let jobs = vec![
        gen_job(0, Family::Banded, 9_000, false),
        gen_job(1, Family::Banded, 9_000, true),
    ];
    let (outcomes, _) = svc.run_batch(jobs);
    assert_eq!(outcomes[0].algo, "pfp", "banded original should route to pfp");
    assert_eq!(
        outcomes[1].algo, "gpu:APFB-GPUBFS-WR-CT-FC",
        "banded RCP should route to the frontier-compacted GPU default"
    );
}

#[test]
fn failure_injection_bad_algo_and_missing_file() {
    let svc = Service::start(2, 4, None);
    // an xla spec without an engine is the build-time failure path
    let bad_algo = gen_job(0, Family::Uniform, 200, false).with_algo("xla:apfb-full");
    let missing = MatchJob::new(1, GraphSource::MtxFile("/nope.mtx".into()));
    let good = gen_job(2, Family::Uniform, 200, false);
    let (outcomes, metrics) = svc.run_batch(vec![bad_algo, missing, good]);
    assert!(outcomes[0].error.is_some());
    assert!(outcomes[1].error.is_some());
    assert!(outcomes[2].error.is_none() && outcomes[2].certified);
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 2);
    assert_eq!(
        metrics.jobs_submitted.load(Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed),
        "every submitted job must be accounted as completed or failed"
    );
    assert_eq!(
        metrics.matched_total.load(Ordering::Relaxed),
        outcomes[2].cardinality as u64,
        "failed jobs must not contribute to matched_total"
    );
}

#[test]
fn deadline_and_cancellation_through_the_service() {
    // zero-deadline jobs fail with the distinct timeout error while a
    // sibling job without a deadline completes normally
    let svc = Service::start(2, 4, None);
    let timed = gen_job(0, Family::Uniform, 500, false).with_timeout_ms(0);
    let fine = gen_job(1, Family::Uniform, 500, false);
    let (outcomes, metrics) = svc.run_batch(vec![timed, fine]);
    assert_eq!(
        outcomes[0].error,
        Some(JobError::DeadlineExceeded { timeout_ms: 0 }),
        "{:?}",
        outcomes[0].error
    );
    assert!(!outcomes[0].certified);
    assert!(outcomes[1].error.is_none() && outcomes[1].certified);
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
    assert_eq!(
        metrics.jobs_submitted.load(Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed)
    );
}

#[test]
fn tcp_server_full_session() {
    let server = Server::bind("127.0.0.1:0", None).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());

    let mut s = TcpStream::connect(addr).unwrap();
    let reqs = [
        "ALGOS",
        "MATCH family=uniform n=400 seed=1 algo=hk init=ks",
        "MATCH family=delaunay n=400 seed=2 permute=1",
        "STATS",
    ];
    for r in reqs {
        s.write_all(r.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let reader = BufReader::new(s.try_clone().unwrap());
    let lines: Vec<String> = reader.lines().take(4).map(|l| l.unwrap()).collect();
    assert!(lines[0].starts_with("ALGOS ") && lines[0].contains("p-dbfs"));
    assert!(lines[1].starts_with("OK ") && lines[1].contains("algo=hk"));
    assert!(lines[2].starts_with("OK ") && lines[2].contains("certified=1"));
    assert!(lines[3].starts_with("STATS ") && lines[3].contains("completed=2"));
    s.write_all(b"QUIT\n").unwrap();
}

#[test]
fn concurrent_tcp_clients() {
    let server = Server::bind("127.0.0.1:0", None).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());
    std::thread::scope(|scope| {
        for i in 0..4 {
            scope.spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                let req = format!("MATCH family=uniform n=300 seed={i} algo=bfs\n");
                s.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                BufReader::new(s).read_line(&mut line).unwrap();
                assert!(line.starts_with("OK "), "{line}");
            });
        }
    });
}
