//! Coordinator integration: service batches, routing behaviour, failure
//! injection, and the TCP server against a live socket.

use bimatch::coordinator::job::{GraphSource, JobError, MatchJob};
use bimatch::coordinator::{Server, Service};
use bimatch::graph::gen::Family;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn gen_job(id: u64, family: Family, n: usize, permute: bool) -> MatchJob {
    MatchJob::new(id, GraphSource::Generate { family, n, seed: id + 1, permute })
}

#[test]
fn service_runs_mixed_trace_certified() {
    let svc = Service::start(2, 8, None);
    let mut jobs = Vec::new();
    for (i, family) in Family::ALL.iter().enumerate() {
        jobs.push(gen_job(i as u64, *family, 600, i % 2 == 0));
    }
    let (outcomes, metrics) = svc.run_batch(jobs);
    assert_eq!(outcomes.len(), Family::ALL.len());
    for o in &outcomes {
        assert!(o.error.is_none(), "{:?}", o.error);
        assert!(o.certified);
        assert!(o.cardinality >= o.init_cardinality);
    }
    assert_eq!(metrics.completed(), Family::ALL.len() as u64);
    assert_eq!(metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    assert_eq!(
        metrics.jobs_submitted.load(std::sync::atomic::Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(std::sync::atomic::Ordering::Relaxed)
    );
}

#[test]
fn router_sends_banded_to_pfp_and_permuted_to_gpu() {
    let svc = Service::start(1, 4, None);
    let jobs = vec![
        gen_job(0, Family::Banded, 9_000, false),
        gen_job(1, Family::Banded, 9_000, true),
    ];
    let (outcomes, _) = svc.run_batch(jobs);
    assert_eq!(outcomes[0].algo, "pfp", "banded original should route to pfp");
    assert_eq!(
        outcomes[1].algo, "gpu:APFB-GPUBFS-WR-CT-FC",
        "banded RCP should route to the frontier-compacted GPU default"
    );
}

#[test]
fn failure_injection_bad_algo_and_missing_file() {
    let svc = Service::start(2, 4, None);
    // an xla spec without an engine is the build-time failure path
    let bad_algo = gen_job(0, Family::Uniform, 200, false).with_algo("xla:apfb-full");
    let missing = MatchJob::new(1, GraphSource::MtxFile("/nope.mtx".into()));
    let good = gen_job(2, Family::Uniform, 200, false);
    let (outcomes, metrics) = svc.run_batch(vec![bad_algo, missing, good]);
    assert!(outcomes[0].error.is_some());
    assert!(outcomes[1].error.is_some());
    assert!(outcomes[2].error.is_none() && outcomes[2].certified);
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 2);
    assert_eq!(
        metrics.jobs_submitted.load(Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed),
        "every submitted job must be accounted as completed or failed"
    );
    assert_eq!(
        metrics.matched_total.load(Ordering::Relaxed),
        outcomes[2].cardinality as u64,
        "failed jobs must not contribute to matched_total"
    );
}

#[test]
fn deadline_and_cancellation_through_the_service() {
    // zero-deadline jobs fail with the distinct timeout error while a
    // sibling job without a deadline completes normally
    let svc = Service::start(2, 4, None);
    let timed = gen_job(0, Family::Uniform, 500, false).with_timeout_ms(0);
    let fine = gen_job(1, Family::Uniform, 500, false);
    let (outcomes, metrics) = svc.run_batch(vec![timed, fine]);
    assert_eq!(
        outcomes[0].error,
        Some(JobError::DeadlineExceeded { timeout_ms: 0 }),
        "{:?}",
        outcomes[0].error
    );
    assert!(!outcomes[0].certified);
    assert!(outcomes[1].error.is_none() && outcomes[1].certified);
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
    assert_eq!(
        metrics.jobs_submitted.load(Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed)
    );
}

#[test]
fn tcp_server_full_session() {
    let server = Server::bind("127.0.0.1:0", None).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());

    let mut s = TcpStream::connect(addr).unwrap();
    let reqs = [
        "ALGOS",
        "MATCH family=uniform n=400 seed=1 algo=hk init=ks",
        "MATCH family=delaunay n=400 seed=2 permute=1",
        "STATS",
    ];
    for r in reqs {
        s.write_all(r.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let reader = BufReader::new(s.try_clone().unwrap());
    let lines: Vec<String> = reader.lines().take(4).map(|l| l.unwrap()).collect();
    assert!(lines[0].starts_with("ALGOS ") && lines[0].contains("p-dbfs"));
    assert!(lines[1].starts_with("OK ") && lines[1].contains("algo=hk"));
    assert!(lines[2].starts_with("OK ") && lines[2].contains("certified=1"));
    assert!(lines[3].starts_with("STATS ") && lines[3].contains("completed=2"));
    s.write_all(b"QUIT\n").unwrap();
}

#[test]
fn tcp_incremental_session_load_update_match_stats_drop() {
    // the acceptance round-trip: LOAD → UPDATE → MATCH → STATS → DROP on
    // one connection, with update jobs visible in the STATS metrics
    let server = Server::bind("127.0.0.1:0", None).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());

    let mut s = TcpStream::connect(addr).unwrap();
    let reqs = [
        "LOAD name=live family=kron n=500 seed=9",
        "MATCH name=live",
        "UPDATE name=live addcols=0;1;2|4;5",
        "UPDATE name=live del=0:0 add=1:0,2:3",
        "MATCH name=live",
        "STATS",
        "DROP name=live",
        "GRAPHS",
    ];
    for r in reqs {
        s.write_all(r.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let reader = BufReader::new(s.try_clone().unwrap());
    let lines: Vec<String> = reader.lines().take(reqs.len()).map(|l| l.unwrap()).collect();
    assert_eq!(lines.len(), reqs.len());
    // LOAD
    assert!(lines[0].starts_with("OK "), "{}", lines[0]);
    assert!(lines[0].contains("name=live"), "{}", lines[0]);
    // first MATCH: certified maximum, establishes the cached matching
    assert!(lines[1].starts_with("OK ") && lines[1].contains("certified=1"), "{}", lines[1]);
    let card = |line: &str| -> u64 {
        line.split_whitespace()
            .find_map(|kv| kv.strip_prefix("card="))
            .unwrap_or_else(|| panic!("card= missing in {line}"))
            .parse()
            .unwrap()
    };
    let card_before = card(&lines[1]);
    // UPDATE replies carry the delta + repair fields and stay certified
    for line in [&lines[2], &lines[3]] {
        assert!(line.starts_with("OK "), "{line}");
        assert!(line.contains("name=live"), "{line}");
        assert!(line.contains("certified=1"), "{line}");
        assert!(line.contains(" inserted="), "{line}");
        assert!(line.contains(" deleted="), "{line}");
        assert!(line.contains(" seeds="), "{line}");
    }
    assert!(lines[2].contains("cols_added=2"), "{}", lines[2]);
    // the repaired matching is served warm and moves by at most the batch
    let card_after = card(&lines[4]);
    assert!(lines[4].contains("certified=1"), "{}", lines[4]);
    assert!(card_after + 2 >= card_before, "{card_before} -> {card_after}");
    // STATS: update jobs visible in metrics, alongside the failure split
    assert!(lines[5].starts_with("STATS "), "{}", lines[5]);
    assert!(lines[5].contains("updated=2"), "{}", lines[5]);
    assert!(lines[5].contains("loaded=1"), "{}", lines[5]);
    assert!(lines[5].contains("timeout=0"), "{}", lines[5]);
    assert!(lines[5].contains("cancelled=0"), "{}", lines[5]);
    // DROP, and the store is empty again
    assert!(lines[6].starts_with("OK ") && lines[6].contains("dropped=1"), "{}", lines[6]);
    assert_eq!(lines[7], "GRAPHS");
    s.write_all(b"QUIT\n").unwrap();
}

#[test]
fn batch_wide_deadline_through_the_service() {
    // satellite regression: a batch-wide budget must trip every job as
    // the distinct DeadlineExceeded failure
    let svc = Service::start(2, 4, None);
    let jobs: Vec<MatchJob> = (0..3).map(|i| gen_job(i, Family::Uniform, 500, false)).collect();
    let (outcomes, metrics) = svc.run_batch_with_timeout_ms(jobs, 0);
    for o in &outcomes {
        assert!(
            matches!(o.error, Some(JobError::DeadlineExceeded { .. })),
            "job {}: {:?}",
            o.job_id,
            o.error
        );
    }
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 3);
    assert_eq!(
        metrics.jobs_submitted.load(Ordering::Relaxed),
        metrics.completed() + metrics.jobs_failed.load(Ordering::Relaxed)
    );
}

#[test]
fn concurrent_tcp_clients() {
    let server = Server::bind("127.0.0.1:0", None).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.serve());
    std::thread::scope(|scope| {
        for i in 0..4 {
            scope.spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                let req = format!("MATCH family=uniform n=300 seed={i} algo=bfs\n");
                s.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                BufReader::new(s).read_line(&mut line).unwrap();
                assert!(line.starts_with("OK "), "{line}");
            });
        }
    });
}
