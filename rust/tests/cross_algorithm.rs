//! Cross-algorithm integration: every registered (non-XLA) matcher must
//! produce a certified maximum matching of identical cardinality on every
//! generator family, original and RCP-permuted, from every init heuristic.

use bimatch::coordinator::registry;
use bimatch::coordinator::spec::AlgoSpec;
use bimatch::graph::gen::Family;
use bimatch::graph::random_permute;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::{reference_max_cardinality, Matching};
use bimatch::MatchingAlgorithm;

fn non_xla_specs() -> Vec<AlgoSpec> {
    registry::all_specs().into_iter().filter(|s| !s.is_xla()).collect()
}

#[test]
fn all_algorithms_agree_on_all_families() {
    for family in Family::ALL {
        let g = family.generate(700, 33);
        let want = reference_max_cardinality(&g);
        let init = InitHeuristic::Cheap.run(&g);
        for spec in non_xla_specs() {
            let algo = registry::build(&spec, None).unwrap();
            let r = algo.run_detached(&g, init.clone());
            r.matching
                .certify(&g)
                .unwrap_or_else(|e| panic!("{spec} on {}: {e}", family.name()));
            assert_eq!(
                r.matching.cardinality(),
                want,
                "{spec} on {}",
                family.name()
            );
        }
    }
}

#[test]
fn all_algorithms_agree_on_permuted_instances() {
    for family in [Family::Banded, Family::Kron, Family::Road] {
        let g = random_permute(&family.generate(600, 5), 99);
        let want = reference_max_cardinality(&g);
        for spec in non_xla_specs() {
            let algo = registry::build(&spec, None).unwrap();
            let r = algo.run_detached(&g, Matching::empty(g.nr, g.nc));
            r.matching.certify(&g).unwrap();
            assert_eq!(r.matching.cardinality(), want, "{spec} on {} rcp", family.name());
        }
    }
}

#[test]
fn init_heuristics_never_change_the_answer() {
    let g = Family::Social.generate(900, 8);
    let want = reference_max_cardinality(&g);
    for init in [InitHeuristic::None, InitHeuristic::Cheap, InitHeuristic::KarpSipser] {
        for name in ["hk", "pfp", "pr", "gpu:APFB-GPUBFS-WR-CT", "p-dbfs"] {
            let algo = registry::build_named(name, None).unwrap();
            let r = algo.run_detached(&g, init.run(&g));
            r.matching.certify(&g).unwrap();
            assert_eq!(r.matching.cardinality(), want, "{name} from {}", init.name());
        }
    }
}

#[test]
fn rectangular_and_degenerate_graphs() {
    use bimatch::graph::gen::random::uniform_random;
    let cases = [
        uniform_random(50, 500, 2.0, 1),   // wide
        uniform_random(500, 50, 10.0, 2),  // tall
        uniform_random(1, 1, 1.0, 3),      // tiny
        bimatch::graph::from_edges(10, 10, &[]), // empty
    ];
    for (i, g) in cases.iter().enumerate() {
        let want = reference_max_cardinality(g);
        for spec in non_xla_specs() {
            let algo = registry::build(&spec, None).unwrap();
            let r = algo.run_detached(g, Matching::empty(g.nr, g.nc));
            r.matching.certify(g).unwrap_or_else(|e| panic!("{spec} case {i}: {e}"));
            assert_eq!(r.matching.cardinality(), want, "{spec} case {i}");
        }
    }
}

#[test]
fn permutation_invariance_of_cardinality() {
    // the matching cardinality is a graph invariant; every algorithm must
    // report the same value before and after RCP
    let g = Family::Amazon.generate(800, 4);
    let p = random_permute(&g, 1234);
    for name in ["hk", "gpu:APFB-GPUBFS-WR-CT", "p-pfp"] {
        let algo = registry::build_named(name, None).unwrap();
        let a = algo.run_detached(&g, Matching::empty(g.nr, g.nc)).matching.cardinality();
        let b = algo.run_detached(&p, Matching::empty(p.nr, p.nc)).matching.cardinality();
        assert_eq!(a, b, "{name}");
    }
}
