//! Property test for the WAL tailing contract that replication rides on:
//! a reader polling `wal::tail_from` while a writer appends — including
//! torn, mid-frame partial writes left visible between two syscalls —
//! must only ever observe a consistent prefix of whole, checksummed
//! frames, in order, and never a torn or corrupted record.

use bimatch::persist::wal::{self, WalRecord};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bimatch_wal_tail_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The same shape the server's UPDATE path logs.
fn upd(v: u64) -> WalRecord {
    WalRecord::Update {
        version_after: v,
        batch_wire: format!("add=0:{v}"),
        report_wire: format!("ins=0:{v} del= cols= rows= rejected=0 rebuilt=0"),
    }
}

/// Raw append without fsync — the torn-write simulator. The real
/// `wal::append` is a single `write_all`, but the OS gives no atomicity
/// for large frames, so the reader must tolerate any split.
fn append_raw(path: &Path, bytes: &[u8]) {
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path).unwrap();
    f.write_all(bytes).unwrap();
}

#[test]
fn concurrent_reader_only_sees_consistent_prefixes() {
    const FRAMES: u64 = 120;
    for trial in 0..3u64 {
        let dir = tempdir(&format!("t{trial}"));
        let path = dir.join("g.wal");
        let mut rng = 0x9E37_79B9_7F4A_7C15u64.wrapping_add(trial);
        let mut next = move || {
            rng = rng
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            rng
        };

        let writer = {
            let path = path.clone();
            std::thread::spawn(move || {
                for v in 1..=FRAMES {
                    let frame = wal::encode_frame(&upd(v));
                    if v % 3 == 0 {
                        // torn write: leave a partial frame on disk for a
                        // moment before completing it
                        let cut = 1 + (next() as usize >> 8) % (frame.len() - 1);
                        append_raw(&path, &frame[..cut]);
                        std::thread::sleep(Duration::from_micros(200));
                        append_raw(&path, &frame[cut..]);
                    } else {
                        append_raw(&path, &frame);
                    }
                }
            })
        };

        // the reader races the writer from offset 0 — before the file
        // even exists (tail_from reports an empty batch for that)
        let mut offset = 0u64;
        let mut seen = 0u64;
        let deadline = Instant::now() + Duration::from_secs(30);
        while seen < FRAMES {
            assert!(
                Instant::now() < deadline,
                "trial {trial}: reader stuck at frame {seen} offset {offset}"
            );
            let (records, new_offset) = wal::tail_from(&path, offset).unwrap();
            assert!(new_offset >= offset, "offset moved backwards");
            for rec in records {
                seen += 1;
                // the exact next record of the prefix — never torn, never
                // reordered, never a checksum-salvaged hybrid
                assert_eq!(rec, upd(seen), "trial {trial}: divergence at frame {seen}");
            }
            offset = new_offset;
            std::thread::sleep(Duration::from_micros(200));
        }
        writer.join().unwrap();

        // quiesced: one full parse agrees and reports a clean tail
        let (records, torn) = wal::read_wal(&path).unwrap();
        assert_eq!(records.len() as u64, FRAMES);
        assert!(!torn, "trial {trial}: quiesced WAL reports torn tail");
        let (tail, end) = wal::tail_from(&path, offset).unwrap();
        assert!(tail.is_empty(), "reader missed frames");
        assert_eq!(end, offset);

        let _ = std::fs::remove_dir_all(&dir);
    }
}
