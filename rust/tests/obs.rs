//! Observability integration: the slow-request event log across
//! non-Complete outcomes, and the `HEALTH` / `DUMP` wire verbs plus the
//! flight-recorder artifacts against a live server.

use bimatch::coordinator::job::{GraphSource, MatchJob};
use bimatch::coordinator::{Executor, Metrics, Server, ServerCfg};
use bimatch::dynamic::DeltaBatch;
use bimatch::graph::gen::Family;
use bimatch::obs::{parse_filter, Obs};
use bimatch::util::json::{parse, Value};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bimatch_obs_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn slow_executor() -> (Executor, Arc<Obs>, Arc<Metrics>) {
    let obs = Obs::in_memory(parse_filter("debug").unwrap(), 64);
    obs.capture_sink();
    let metrics = Arc::new(Metrics::new());
    let e = Executor::new(None, metrics.clone())
        .with_obs(obs.clone())
        .with_slow_threshold(Duration::ZERO);
    (e, obs, metrics)
}

/// The `slow_job` lines an operator would have seen, parsed.
fn slow_events(obs: &Obs) -> Vec<Value> {
    obs.captured()
        .into_iter()
        .map(|l| parse(&l).unwrap_or_else(|e| panic!("unparseable event {l:?}: {e}")))
        .filter(|v| v.get("event").and_then(Value::as_str) == Some("slow_job"))
        .collect()
}

fn str_field<'a>(v: &'a Value, key: &str) -> &'a str {
    v.get(key).and_then(Value::as_str).unwrap_or_else(|| panic!("{key} missing in {v:?}"))
}

#[test]
fn slow_log_carries_timeout_outcome() {
    let (e, obs, metrics) = slow_executor();
    let job = MatchJob::new(1, GraphSource::Generate { family: Family::Uniform, n: 400, seed: 3, permute: false })
        .with_timeout_ms(0);
    let out = e.execute(&job);
    assert!(out.error.is_some(), "a zero deadline must trip");
    assert_eq!(metrics.jobs_slow.load(Ordering::Relaxed), 1);
    let slow = slow_events(&obs);
    assert_eq!(slow.len(), 1, "{slow:?}");
    assert_eq!(str_field(&slow[0], "outcome"), "timeout");
    assert_eq!(str_field(&slow[0], "level"), "warn");
    assert_eq!(str_field(&slow[0], "op"), "match");
}

#[test]
fn slow_log_carries_cancelled_outcome() {
    let (e, obs, metrics) = slow_executor();
    e.cancel_token().cancel();
    let job = MatchJob::new(1, GraphSource::Generate { family: Family::Uniform, n: 400, seed: 3, permute: false });
    let out = e.execute(&job);
    assert!(out.error.is_some(), "a cancelled executor must fail the job");
    assert_eq!(metrics.jobs_slow.load(Ordering::Relaxed), 1);
    let slow = slow_events(&obs);
    assert_eq!(slow.len(), 1, "{slow:?}");
    assert_eq!(str_field(&slow[0], "outcome"), "cancelled");
}

#[test]
fn slow_log_marks_rolled_back_updates() {
    let (e, obs, metrics) = slow_executor();
    let g = Arc::new(Family::Uniform.generate(400, 3));
    let out = e.execute(&MatchJob::load_graph(1, "g", GraphSource::InMemory(g)));
    assert!(out.error.is_none(), "{:?}", out.error);
    let slow_before = metrics.jobs_slow.load(Ordering::Relaxed);
    let _ = obs.captured(); // discard the load's own slow line

    // a zero deadline fails the repair and rolls the stored graph back
    let batch = DeltaBatch::new().insert(0, 1).insert(1, 0);
    let out = e.execute(&MatchJob::update_graph(2, "g", batch).with_timeout_ms(0));
    assert!(out.error.is_some(), "a zero deadline must trip the update");
    assert_eq!(metrics.jobs_slow.load(Ordering::Relaxed), slow_before + 1);
    let slow = slow_events(&obs);
    assert_eq!(slow.len(), 1, "{slow:?}");
    assert_eq!(str_field(&slow[0], "op"), "update");
    assert_eq!(str_field(&slow[0], "outcome"), "timeout");
    assert_eq!(
        slow[0].get("rolled_back").and_then(Value::as_bool),
        Some(true),
        "{:?}",
        slow[0]
    );
}

fn start_server(data_dir: Option<PathBuf>) -> (Server, SocketAddr) {
    let mut cfg = ServerCfg::new("127.0.0.1:0");
    cfg.data_dir = data_dir;
    let server = Server::bind_cfg(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    (server, addr)
}

fn roundtrip(addr: SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

#[test]
fn health_verb_reports_identity() {
    let (server, addr) = start_server(None);
    std::thread::spawn(move || server.serve());
    roundtrip(addr, "LOAD name=g family=uniform n=300 seed=5");
    let reply = roundtrip(addr, "HEALTH");
    assert!(reply.starts_with("HEALTH role=primary epoch="), "{reply}");
    for key in ["version=", "git=", "uptime_s=", "graphs=1"] {
        assert!(reply.contains(key), "{key} missing in {reply}");
    }
}

#[test]
fn dump_verb_writes_a_parseable_flight_record() {
    let dir = tempdir("dump");
    let (server, addr) = start_server(Some(dir.clone()));
    std::thread::spawn(move || server.serve());
    roundtrip(addr, "LOAD name=g family=uniform n=300 seed=5");
    roundtrip(addr, "MATCH name=g");

    let reply = roundtrip(addr, "DUMP");
    assert!(reply.starts_with("OK dump="), "{reply}");
    let path = reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix("dump="))
        .unwrap()
        .to_string();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 2, "header plus at least one event: {lines:?}");
    let header = parse(lines[0]).unwrap();
    assert_eq!(str_field(&header, "schema"), "bimatch-flightrec/1");
    assert_eq!(str_field(&header, "reason"), "request");
    for l in &lines[1..] {
        let v = parse(l).unwrap_or_else(|e| panic!("unparseable dump line {l:?}: {e}"));
        assert!(v.get("event").is_some(), "{l}");
    }
    // the server also left an events.jsonl trail of the same activity
    let events = std::fs::read_to_string(dir.join("events.jsonl")).unwrap();
    assert!(events.lines().any(|l| l.contains("\"server_started\"")), "{events}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn clean_shutdown_leaves_latest_flight_record() {
    let dir = tempdir("latest");
    let (server, addr) = start_server(Some(dir.clone()));
    let stop = server.stop_handle();
    let serve = std::thread::spawn(move || server.serve());
    roundtrip(addr, "LOAD name=g family=uniform n=300 seed=5");
    stop.store(true, Ordering::Relaxed);
    serve.join().unwrap().unwrap();

    let text = std::fs::read_to_string(dir.join("flightrec").join("latest.jsonl")).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let header = parse(lines[0]).unwrap();
    assert_eq!(str_field(&header, "schema"), "bimatch-flightrec/1");
    assert!(
        lines[1..].iter().any(|l| l.contains("\"server_started\"")),
        "the flushed ring must hold the lifecycle events: {lines:?}"
    );
    for l in &lines[1..] {
        parse(l).unwrap_or_else(|e| panic!("unparseable line {l:?}: {e}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}
