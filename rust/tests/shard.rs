//! Sharded-execution integration: a sharded run must reach the unsharded
//! cardinality for every shard count × generator family × frontier mode,
//! and the modeled interconnect charge must respect the partitioner's
//! boundary-edge bound.

use bimatch::coordinator::registry;
use bimatch::gpu::device::EXCHANGE_WORDS_PER_ITEM;
use bimatch::gpu::GpuConfig;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::matching::{reference_max_cardinality, Matching};
use bimatch::shard::{ColPartition, ShardedGpuMatcher};
use bimatch::MatchingAlgorithm;

/// The acceptance matrix: K ∈ {1, 2, 4, 8} × every generator family ×
/// {FullScan, Compacted} all agree with the reference cardinality.
#[test]
fn sharded_matches_reference_for_every_family_shard_count_and_mode() {
    for family in Family::ALL {
        let g = family.generate(600, 21);
        let want = reference_max_cardinality(&g);
        let init = InitHeuristic::Cheap.run(&g);
        for cfg in [GpuConfig::default(), GpuConfig::default().compacted()] {
            for k in [1usize, 2, 4, 8] {
                let algo = ShardedGpuMatcher::new(cfg, k);
                let r = algo.run_detached(&g, init.clone());
                r.matching
                    .certify(&g)
                    .unwrap_or_else(|e| panic!("{} on {}: {e}", algo.name(), family.name()));
                assert_eq!(
                    r.matching.cardinality(),
                    want,
                    "{} on {}",
                    algo.name(),
                    family.name()
                );
                assert_eq!(r.stats.shards, k as u64, "{}", algo.name());
            }
        }
    }
}

/// Random graphs: sharded cardinality equals the reference for every
/// shard count, from an empty initial matching.
#[test]
fn prop_sharded_matches_reference_on_random_graphs() {
    use bimatch::util::qcheck::{arb_bipartite, forall, Config};
    forall(Config::cases(20), |rng| {
        let (nr, nc, edges) = arb_bipartite(rng, 30);
        let g = bimatch::graph::from_edges(nr, nc, &edges);
        let want = reference_max_cardinality(&g);
        for k in [1usize, 2, 4, 8] {
            let algo = ShardedGpuMatcher::new(GpuConfig::default().compacted(), k);
            let r = algo.run_detached(&g, Matching::empty(nr, nc));
            r.matching.certify(&g).map_err(|e| e.to_string())?;
            if r.matching.cardinality() != want {
                return Err(format!("shard{k} suboptimal: {}", r.matching.cardinality()));
            }
        }
        Ok(())
    });
}

/// Interconnect invariants: every routed item is a cross-shard column
/// claim, each claimed column crosses at most once per phase, and a
/// cross-shard claim travels an edge incident to a boundary row — so the
/// total words are a multiple of the per-item size and bounded by
/// `phases × boundary_edge_count` items. One shard routes nothing.
#[test]
fn exchange_charge_is_bounded_by_boundary_edges() {
    for family in [Family::Uniform, Family::Kron, Family::Road, Family::Banded] {
        let g = family.generate(900, 13);
        for cfg in [GpuConfig::default(), GpuConfig::default().compacted()] {
            for k in [2usize, 4, 8] {
                let part = ColPartition::new(&g, k);
                let boundary = part.boundary_edge_count(&g);
                let algo = ShardedGpuMatcher::new(cfg, k);
                let r = algo.run_detached(&g, InitHeuristic::Cheap.run(&g));
                let words = r.stats.exchange_words;
                assert_eq!(
                    words % EXCHANGE_WORDS_PER_ITEM,
                    0,
                    "{} on {}: fractional items",
                    algo.name(),
                    family.name()
                );
                assert!(
                    words / EXCHANGE_WORDS_PER_ITEM <= r.stats.phases * boundary,
                    "{} on {}: {} routed items exceed {} phases x {} boundary edges",
                    algo.name(),
                    family.name(),
                    words / EXCHANGE_WORDS_PER_ITEM,
                    r.stats.phases,
                    boundary
                );
            }
        }
        let single = ShardedGpuMatcher::new(GpuConfig::default().compacted(), 1);
        let r = single.run_detached(&g, InitHeuristic::Cheap.run(&g));
        assert_eq!(r.stats.exchange_words, 0, "one shard must route nothing");
        assert_eq!(r.stats.exchange_steps, 0, "one shard must route nothing");
    }
}

/// The registry path end to end: a `shard<K>:gpu:…` name builds a matcher
/// that agrees with its unsharded inner variant.
#[test]
fn registry_built_sharded_matcher_agrees_with_unsharded() {
    let g = Family::Social.generate(800, 9);
    let init = InitHeuristic::Cheap.run(&g);
    let unsharded = registry::build_named("gpu:APFB-GPUBFS-WR-CT-FC", None).unwrap();
    let want = unsharded.run_detached(&g, init.clone()).matching.cardinality();
    for name in ["shard2:gpu:APFB-GPUBFS-WR-CT-FC", "shard4:gpu:APsB-GPUBFS-CT", "shard8:gpu"] {
        let algo = registry::build_named(name, None).unwrap();
        let r = algo.run_detached(&g, init.clone());
        r.matching.certify(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(r.matching.cardinality(), want, "{name}");
    }
}
