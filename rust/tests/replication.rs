//! End-to-end replication and failover: a primary serving the TCP verb
//! protocol, a read replica tailing its WAL-frame stream, quorum acks,
//! crash promotion, and epoch fencing of the rejoining ex-primary.

use bimatch::coordinator::{Server, ServerCfg};
use bimatch::persist::replicate::AckMode;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bimatch_repl_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

struct Node {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    serve: Option<JoinHandle<std::io::Result<()>>>,
}

impl Node {
    fn start(mut cfg: ServerCfg) -> Node {
        cfg.addr = "127.0.0.1:0".into();
        let server = Server::bind_cfg(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_handle();
        let serve = std::thread::spawn(move || server.serve());
        Node { addr, stop, serve: Some(serve) }
    }

    /// Clean stop: drain, fsync, join — then the listener is gone.
    fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.serve.take() {
            h.join().unwrap().unwrap();
        }
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.serve.take() {
            let _ = h.join();
        }
    }
}

fn roundtrip(addr: SocketAddr, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim().to_string()
}

fn field(reply: &str, name: &str) -> u64 {
    reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix(name))
        .unwrap_or_else(|| panic!("{name} missing in {reply}"))
        .parse()
        .unwrap_or_else(|e| panic!("bad {name} in {reply}: {e}"))
}

/// Poll `probe` until it returns true or the deadline trips.
fn wait_for(what: &str, mut probe: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn follower_tails_primary_serves_reads_and_rejects_writes() {
    let primary = Node::start(ServerCfg::new(""));
    assert!(roundtrip(primary.addr, "LOAD name=g family=uniform n=500 seed=7")
        .starts_with("OK "));
    let primary_match = roundtrip(primary.addr, "MATCH name=g");
    assert!(primary_match.contains("certified=1"), "{primary_match}");
    let card = field(&primary_match, "card=");

    let mut fcfg = ServerCfg::new("");
    fcfg.replicate_from = Some(primary.addr.to_string());
    let follower = Node::start(fcfg);

    // the baseline snapshot replicates the already-loaded graph
    wait_for("baseline replication of g", || {
        roundtrip(follower.addr, "GRAPHS") == "GRAPHS g"
    });
    let reply = roundtrip(follower.addr, "MATCH name=g");
    assert!(reply.contains("certified=1"), "{reply}");
    assert_eq!(field(&reply, "card="), card, "replicated graph must match the primary's");

    // a write committed on the primary streams over as a frame and is
    // replayed through the recovery path on the follower
    wait_for("primary sees its follower", || {
        roundtrip(primary.addr, "LAG").contains("followers=1")
    });
    let reply = roundtrip(primary.addr, "UPDATE name=g addcols=0;1;2");
    assert!(reply.starts_with("OK "), "{reply}");
    let card_after = field(&reply, "card=");
    wait_for("follower to apply the streamed update", || {
        field(&roundtrip(follower.addr, "MATCH name=g"), "card=") == card_after
    });
    let reply = roundtrip(follower.addr, "MATCH name=g");
    assert!(reply.contains("certified=1"), "{reply}");

    // the replica is read-only: every write verb bounces, typed
    for req in [
        "UPDATE name=g add=0:0",
        "LOAD name=h family=uniform n=50 seed=1",
        "DROP name=g",
        "SAVE name=g",
    ] {
        let reply = roundtrip(follower.addr, req);
        assert!(reply.starts_with("ERR read-only"), "{req} → {reply}");
    }
    let lag = roundtrip(follower.addr, "LAG");
    assert!(lag.contains("role=follower"), "{lag}");
    assert!(lag.contains("connected=1"), "{lag}");

    // a DROP on the primary propagates too
    assert!(roundtrip(primary.addr, "DROP name=g").starts_with("OK "));
    wait_for("follower to apply the streamed drop", || {
        roundtrip(follower.addr, "GRAPHS") == "GRAPHS"
    });
}

#[test]
fn quorum_write_without_a_follower_fails_as_in_doubt() {
    let mut cfg = ServerCfg::new("");
    cfg.ack_mode = AckMode::Quorum;
    cfg.ack_timeout = Some(Duration::from_millis(150));
    let primary = Node::start(cfg);
    // no follower connected: the write commits locally but cannot be
    // confirmed — the reply is the typed in-doubt error, not silence
    let reply = roundtrip(primary.addr, "LOAD name=g family=uniform n=200 seed=3");
    assert!(reply.starts_with("ERR replication:"), "{reply}");
    assert!(reply.contains("durable locally"), "{reply}");
    // the local commit is real: the graph is there and reads serve it
    assert_eq!(roundtrip(primary.addr, "GRAPHS"), "GRAPHS g");
    assert!(roundtrip(primary.addr, "MATCH name=g").contains("certified=1"));
    let stats = roundtrip(primary.addr, "STATS");
    assert!(stats.contains("shipped=1"), "{stats}");
}

#[test]
fn promotion_fails_over_with_zero_acked_loss_and_fences_the_ex_primary() {
    let primary_dir = tempdir("promote_primary");
    let follower_dir = tempdir("promote_follower");

    // quorum primary: an OK'd write is GUARANTEED applied on the follower
    let mut pcfg = ServerCfg::new("");
    pcfg.data_dir = Some(primary_dir.clone());
    pcfg.ack_mode = AckMode::Quorum;
    pcfg.ack_timeout = Some(Duration::from_secs(10));
    let mut primary = Node::start(pcfg);

    let mut fcfg = ServerCfg::new("");
    fcfg.data_dir = Some(follower_dir.clone());
    fcfg.replicate_from = Some(primary.addr.to_string());
    let follower = Node::start(fcfg);
    wait_for("follower stream to come up", || {
        roundtrip(primary.addr, "LAG").contains("followers=1")
    });

    assert!(roundtrip(primary.addr, "LOAD name=g family=uniform n=1500 seed=7")
        .starts_with("OK "));
    // cold MATCH on the primary: the phase count a from-scratch compute
    // needs (also seeds the cached matching that UPDATE repairs)
    let cold = roundtrip(primary.addr, "MATCH name=g");
    assert!(cold.contains("certified=1"), "{cold}");
    let cold_phases = field(&cold, "phases=");
    // warm the follower too: reads are allowed on a replica, and the
    // cached maximum it computes here is what streamed update frames
    // repair forward — keeping the node one seeded repair from certified
    let reply = roundtrip(follower.addr, "MATCH name=g");
    assert!(reply.contains("certified=1"), "{reply}");
    assert_eq!(field(&reply, "card="), field(&cold, "card="));
    // acked writes: quorum means each OK implies the follower applied it
    let mut card = 0;
    for i in 0..3 {
        let reply =
            roundtrip(primary.addr, &format!("UPDATE name=g addcols={i};{}", i + 50));
        assert!(reply.starts_with("OK "), "{reply}");
        card = field(&reply, "card=");
    }

    // primary dies (clean stop here; SIGKILL chaos lives in CI)
    primary.stop();

    // crash-promote the follower: it fences the dead primary's epoch and
    // becomes writable
    let reply = roundtrip(follower.addr, "PROMOTE");
    assert!(reply.starts_with("OK promoted=1"), "{reply}");
    let promoted_epoch = field(&reply, "epoch=");
    assert!(promoted_epoch >= 1, "{reply}");
    assert_eq!(field(&reply, "graphs="), 1, "{reply}");

    // zero acked loss: the promoted node serves the exact acked state,
    // certified, via seeded repair — warm, not a cold recompute
    let warm = roundtrip(follower.addr, "MATCH name=g");
    assert!(warm.contains("certified=1"), "{warm}");
    assert_eq!(field(&warm, "card="), card, "acked update lost across failover: {warm}");
    let warm_phases = field(&warm, "phases=");
    assert!(warm_phases <= cold_phases, "warm {warm_phases} > cold {cold_phases}: {warm}");
    if cold_phases > 1 {
        assert!(
            warm_phases < cold_phases,
            "promoted MATCH must warm-start (repair phases {warm_phases} \
             vs cold {cold_phases}): {warm}"
        );
    }
    // and the promoted node takes writes
    let reply = roundtrip(follower.addr, "UPDATE name=g addcols=3;4");
    assert!(reply.starts_with("OK "), "{reply}");
    let lag = roundtrip(follower.addr, "LAG");
    assert!(lag.contains("role=primary"), "{lag}");

    // the ex-primary rejoins: a handshake carrying the promoted epoch
    // fences it — it refuses the stream and stops accepting writes
    let mut ecfg = ServerCfg::new("");
    ecfg.data_dir = Some(primary_dir.clone());
    let ex_primary = Node::start(ecfg);
    assert_eq!(roundtrip(ex_primary.addr, "GRAPHS"), "GRAPHS g", "ex-primary recovers");
    let reply = roundtrip(ex_primary.addr, &format!("REPLICA epoch={promoted_epoch}"));
    assert!(reply.starts_with("ERR fenced:"), "{reply}");
    let reply = roundtrip(ex_primary.addr, "UPDATE name=g addcols=9;10");
    assert!(reply.starts_with("ERR read-only"), "split-brain write accepted: {reply}");
    assert!(roundtrip(ex_primary.addr, "LAG").contains("role=fenced"));
    // reads still flow on the fenced node
    assert!(roundtrip(ex_primary.addr, "MATCH name=g").contains("certified=1"));

    let _ = std::fs::remove_dir_all(&primary_dir);
    let _ = std::fs::remove_dir_all(&follower_dir);
}

#[test]
fn durable_follower_survives_its_own_restart() {
    let follower_dir = tempdir("follower_restart");
    let primary = Node::start(ServerCfg::new(""));
    assert!(roundtrip(primary.addr, "LOAD name=g family=uniform n=400 seed=9")
        .starts_with("OK "));

    let mut fcfg = ServerCfg::new("");
    fcfg.data_dir = Some(follower_dir.clone());
    fcfg.replicate_from = Some(primary.addr.to_string());
    let mut follower = Node::start(fcfg);
    wait_for("baseline replication", || {
        roundtrip(follower.addr, "GRAPHS") == "GRAPHS g"
    });
    let card = field(&roundtrip(follower.addr, "MATCH name=g"), "card=");
    // the follower persisted what it acked: a restart recovers the
    // replicated graph from its own data dir before re-tailing
    follower.stop();
    let mut fcfg = ServerCfg::new("");
    fcfg.data_dir = Some(follower_dir.clone());
    fcfg.replicate_from = Some(primary.addr.to_string());
    let follower = Node::start(fcfg);
    assert_eq!(roundtrip(follower.addr, "GRAPHS"), "GRAPHS g");
    let reply = roundtrip(follower.addr, "MATCH name=g");
    assert!(reply.contains("certified=1"), "{reply}");
    assert_eq!(field(&reply, "card="), card);

    let _ = std::fs::remove_dir_all(&follower_dir);
}
