//! Golden-shape trace tests: an armed run's span timeline must tell the
//! same story as the counters the matchers already report. Two anchors:
//! the per-phase `launches` args of a `gpu:*-FC` run reproduce
//! `RunStats.launches_per_phase` exactly (the paper's Fig. 2 pairing),
//! and a `shard4:` run's BSP-track span durations telescope to the
//! modeled parallel makespan (`RunStats.device_parallel_cycles`).

use bimatch::coordinator::registry;
use bimatch::graph::gen::Family;
use bimatch::matching::init::InitHeuristic;
use bimatch::trace::{TraceBuf, BSP_TRACK, DEVICE_TRACK_BASE, HOST_TRACK};
use bimatch::{MatchingAlgorithm, RunCtx};

#[test]
fn gpu_fc_phase_spans_reproduce_launches_per_phase() {
    let g = Family::Road.generate(1200, 7);
    let init = InitHeuristic::Cheap.run(&g);
    let algo = registry::build_named("gpu:APFB-GPUBFS-WR-CT-FC", None).unwrap();
    let mut ctx = RunCtx::detached();
    ctx.arm_trace(TraceBuf::new());
    let r = algo.run(&g, init, &mut ctx);
    r.matching.certify(&g).unwrap();
    let buf = ctx.take_trace().expect("armed buffer comes back");
    assert_eq!(buf.dropped(), 0, "default capacity must hold a full run");
    // golden shape: one host "phase" span per phase, whose launches arg
    // is launches_per_phase verbatim, in order
    let phase_launches: Vec<u64> = buf
        .spans()
        .iter()
        .filter(|s| s.cat == "phase" && s.track == HOST_TRACK)
        .map(|s| s.args.iter().find(|(k, _)| *k == "launches").expect("launches arg").1)
        .collect();
    let want: Vec<u64> = r.stats.launches_per_phase.iter().map(|&l| l as u64).collect();
    assert!(!want.is_empty(), "a real run has phases");
    assert_eq!(phase_launches, want);
    assert_eq!(phase_launches.len() as u64, r.stats.phases);
    // kernel spans live on shard 0's device track, in modeled cycles that
    // never overrun the run's total device bill
    let kernels: Vec<_> = buf
        .spans()
        .iter()
        .filter(|s| s.cat == "kernel" && s.track == DEVICE_TRACK_BASE)
        .collect();
    assert!(!kernels.is_empty());
    for k in &kernels {
        assert!(k.ts + k.dur <= r.stats.device_cycles, "{}: {}+{}", k.name, k.ts, k.dur);
    }
    for name in ["init_bfs_array", "gpubfs_wr_frontier", "alternate", "fixmatching"] {
        assert!(kernels.iter().any(|k| k.name == name), "missing kernel span {name}");
    }
    // the compacted BFS sweeps carry their frontier sizes (Fig. 2's
    // per-level workload), bounded by the run's recorded peak
    let frontiers: Vec<u64> = kernels
        .iter()
        .filter(|k| k.name == "gpubfs_wr_frontier")
        .filter_map(|k| k.args.iter().find(|(n, _)| *n == "frontier").map(|&(_, v)| v))
        .collect();
    assert!(!frontiers.is_empty(), "compacted sweeps must report frontier sizes");
    assert_eq!(
        frontiers.iter().copied().max().unwrap(),
        r.stats.frontier_peak,
        "largest traced frontier must be the recorded peak"
    );
}

#[test]
fn sharded_bsp_spans_telescope_to_the_parallel_makespan() {
    let g = Family::Uniform.generate(1500, 11);
    let init = InitHeuristic::Cheap.run(&g);
    let algo = registry::build_named("shard4:gpu:APFB-GPUBFS-WR-CT-FC", None).unwrap();
    let mut ctx = RunCtx::detached();
    ctx.arm_trace(TraceBuf::new());
    let r = algo.run(&g, init, &mut ctx);
    r.matching.certify(&g).unwrap();
    let buf = ctx.take_trace().expect("armed buffer comes back");
    assert_eq!(buf.dropped(), 0, "default capacity must hold a sharded run");
    assert_eq!(r.stats.shards, 4);
    let bsp: Vec<_> = buf.spans().iter().filter(|s| s.track == BSP_TRACK).collect();
    assert!(!bsp.is_empty());
    // the BSP decomposition: spans are contiguous intervals on the
    // makespan axis whose durations sum to the exact parallel bill —
    // instrumentation only reads the clocks it narrates
    let mut cursor = 0u64;
    for sp in &bsp {
        assert_eq!(sp.ts, cursor, "{}: BSP spans must tile without gaps", sp.name);
        cursor += sp.dur;
    }
    assert_eq!(
        bsp.iter().map(|s| s.dur).sum::<u64>(),
        r.stats.device_parallel_cycles,
        "BSP span durations must telescope to the modeled parallel makespan"
    );
    // the per-level exchange narration reproduces the interconnect bill
    let words_traced: u64 = bsp
        .iter()
        .filter(|s| s.name == "level")
        .filter_map(|s| s.args.iter().find(|(n, _)| *n == "exchange_words").map(|&(_, v)| v))
        .sum();
    assert_eq!(words_traced, r.stats.exchange_words);
    // uniform random edges scatter claims across 4 shards: something moved
    assert!(words_traced > 0, "uniform family must exchange");
}
