//! Evaluation harness: instance catalog ([`catalog`]), measurement runner
//! with on-disk caching ([`eval`]), and the paper's aggregations
//! ([`report`]). Each `rust/benches/bench_*.rs` binary regenerates one
//! table or figure from these pieces.

pub mod catalog;
pub mod eval;
pub mod report;

pub use catalog::{Instance, Scale};
pub use eval::{Evaluator, Record, Subsets};
