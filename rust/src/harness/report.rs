//! Table/figure assembly from evaluation records: the exact aggregations
//! of the paper's §4 (geometric means over instance sets, speedup
//! profiles, performance profiles, overall speedup bars).

use super::eval::Record;
use crate::util::stats::{geomean, performance_profile, speedup_profile, ProfilePoint};
use std::collections::HashMap;

/// Geomean of a metric over the records of one algorithm restricted to an
/// instance set.
pub fn geomean_over(
    records: &[Record],
    algo: &str,
    instances: &[String],
    metric: impl Fn(&Record) -> f64,
) -> f64 {
    let set: std::collections::HashSet<&String> = instances.iter().collect();
    let vals: Vec<f64> = records
        .iter()
        .filter(|r| r.algo == algo && set.contains(&r.instance))
        .map(metric)
        .collect();
    geomean(&vals)
}

/// speedups[i] = t_ref(i) / t_algo(i) for instances where both exist.
pub fn speedups(
    records: &[Record],
    algo: &str,
    reference_best_of: &[&str],
    instances: &[String],
) -> Vec<f64> {
    let by_key: HashMap<(&str, &str), f64> = records
        .iter()
        .map(|r| ((r.instance.as_str(), r.algo.as_str()), r.wall_secs))
        .collect();
    instances
        .iter()
        .filter_map(|inst| {
            let t_ref = reference_best_of
                .iter()
                .filter_map(|a| by_key.get(&(inst.as_str(), *a)))
                .cloned()
                .fold(f64::INFINITY, f64::min);
            let t = by_key.get(&(inst.as_str(), algo))?;
            if t_ref.is_finite() {
                Some(t_ref / t.max(1e-9))
            } else {
                None
            }
        })
        .collect()
}

/// Fig. 3: log2-scaled speedup profile of each algorithm vs the best
/// sequential reference.
pub fn fig3_profiles(
    records: &[Record],
    algos: &[&str],
    seq_refs: &[&str],
    instances: &[String],
    xs: &[f64],
) -> Vec<(String, Vec<ProfilePoint>)> {
    algos
        .iter()
        .map(|a| {
            let sp = speedups(records, a, seq_refs, instances);
            (a.to_string(), speedup_profile(&sp, xs))
        })
        .collect()
}

/// Fig. 4: performance profiles of the given algorithms.
pub fn fig4_profiles(
    records: &[Record],
    algos: &[&str],
    instances: &[String],
    xs: &[f64],
) -> Vec<(String, Vec<ProfilePoint>)> {
    let by_key: HashMap<(&str, &str), f64> = records
        .iter()
        .map(|r| ((r.instance.as_str(), r.algo.as_str()), r.wall_secs))
        .collect();
    // keep only instances where every algorithm has a record
    let usable: Vec<&String> = instances
        .iter()
        .filter(|i| algos.iter().all(|a| by_key.contains_key(&(i.as_str(), *a))))
        .collect();
    let times: Vec<Vec<f64>> = algos
        .iter()
        .map(|a| {
            usable
                .iter()
                .map(|i| by_key[&(i.as_str(), *a)])
                .collect()
        })
        .collect();
    let profs = performance_profile(&times, xs);
    algos
        .iter()
        .map(|a| a.to_string())
        .zip(profs)
        .collect()
}

/// Fig. 5: overall geomean speedup of `algo` w.r.t. each reference.
pub fn fig5_overall(
    records: &[Record],
    algo: &str,
    refs: &[&str],
    instances: &[String],
) -> Vec<(String, f64)> {
    refs.iter()
        .map(|r| {
            let sp = speedups(records, algo, &[*r], instances);
            (r.to_string(), geomean(&sp))
        })
        .collect()
}

/// Fraction of instances where `algo` beats `other` (paper §4 "faster on
/// 86% of the original graphs").
pub fn win_rate(records: &[Record], algo: &str, other: &str, instances: &[String]) -> f64 {
    let by_key: HashMap<(&str, &str), f64> = records
        .iter()
        .map(|r| ((r.instance.as_str(), r.algo.as_str()), r.wall_secs))
        .collect();
    let mut wins = 0usize;
    let mut total = 0usize;
    for inst in instances {
        if let (Some(a), Some(b)) = (
            by_key.get(&(inst.as_str(), algo)),
            by_key.get(&(inst.as_str(), other)),
        ) {
            total += 1;
            if a < b {
                wins += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        wins as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(instance: &str, algo: &str, secs: f64) -> Record {
        Record {
            instance: instance.into(),
            algo: algo.into(),
            wall_secs: secs,
            device_ms: 0.0,
            device_parallel_ms: 0.0,
            cardinality: 1,
            phases: 1,
        }
    }

    fn sample() -> (Vec<Record>, Vec<String>) {
        let records = vec![
            rec("a", "gpu", 1.0),
            rec("a", "hk", 4.0),
            rec("a", "pfp", 2.0),
            rec("b", "gpu", 2.0),
            rec("b", "hk", 2.0),
            rec("b", "pfp", 8.0),
        ];
        (records, vec!["a".into(), "b".into()])
    }

    #[test]
    fn speedups_vs_best_seq() {
        let (records, insts) = sample();
        let sp = speedups(&records, "gpu", &["hk", "pfp"], &insts);
        // a: best seq = 2.0 → 2x; b: best seq = 2.0 → 1x
        assert_eq!(sp, vec![2.0, 1.0]);
    }

    #[test]
    fn geomean_over_set() {
        let (records, insts) = sample();
        let g = geomean_over(&records, "gpu", &insts, |r| r.wall_secs);
        assert!((g - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn fig5_and_winrate() {
        let (records, insts) = sample();
        let overall = fig5_overall(&records, "gpu", &["hk", "pfp"], &insts);
        assert_eq!(overall.len(), 2);
        assert!(overall.iter().all(|(_, v)| *v >= 1.0));
        assert_eq!(win_rate(&records, "gpu", "pfp", &insts), 1.0);
        assert_eq!(win_rate(&records, "gpu", "hk", &insts), 0.5);
    }

    #[test]
    fn fig34_shapes() {
        let (records, insts) = sample();
        let xs = vec![-1.0, 0.0, 1.0, 2.0];
        let f3 = fig3_profiles(&records, &["gpu", "hk"], &["hk", "pfp"], &insts, &xs);
        assert_eq!(f3.len(), 2);
        assert_eq!(f3[0].1.len(), xs.len());
        let f4 = fig4_profiles(&records, &["gpu", "hk", "pfp"], &insts, &[1.0, 2.0, 4.0]);
        assert_eq!(f4.len(), 3);
        // gpu is within 1x of best on instance a, within 1x on b (tie 2.0)
        assert!((f4[0].1[0].y - 1.0).abs() < 1e-12);
    }
}
