//! The instance catalog: the synthetic stand-in for the paper's 70-matrix
//! UFL collection (DESIGN.md §2). Every instance is `(family, n, seed)`;
//! the RCP variant applies a seeded random row+column permutation exactly
//! as the paper's second instance set does.
//!
//! Sizes honour `BIMATCH_SCALE`:
//!   `small` (default) — n per side ≈ 2.5k–10k, the whole evaluation runs
//!   in minutes on one CPU;
//!   `large` — ≈ 4× bigger, for the perf pass.

use crate::graph::csr::BipartiteCsr;
use crate::graph::gen::Family;
use crate::graph::random_permute;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Instance {
    pub family: Family,
    pub n: usize,
    pub seed: u64,
    /// RCP variant (random row+column permutation)
    pub permuted: bool,
}

impl Instance {
    pub fn name(&self) -> String {
        let base = format!("{}_{}k_s{}", self.family.name(), self.n / 1000, self.seed);
        if self.permuted {
            format!("{base}_rcp")
        } else {
            base
        }
    }

    pub fn build(&self) -> BipartiteCsr {
        let g = self.family.generate(self.n, self.seed);
        if self.permuted {
            random_permute(&g, self.seed.wrapping_mul(0x9E37).wrapping_add(17))
        } else {
            g
        }
    }

    pub fn rcp(&self) -> Instance {
        Instance { permuted: true, ..*self }
    }
}

/// Evaluation scale from `BIMATCH_SCALE`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Small,
    Large,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("BIMATCH_SCALE").as_deref() {
            Ok("large") => Scale::Large,
            _ => Scale::Small,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scale::Small => "small",
            Scale::Large => "large",
        }
    }

    fn factor(&self) -> usize {
        match self {
            Scale::Small => 1,
            Scale::Large => 4,
        }
    }
}

/// The "original" (non-permuted) catalog: 2 sizes × 10 families + extra
/// seeds on the families the paper's Hardest20 over-represents.
pub fn original(scale: Scale) -> Vec<Instance> {
    let f = scale.factor();
    let mut v = Vec::new();
    for family in Family::ALL {
        for (n, seed) in [(2_500 * f, 1u64), (9_000 * f, 2u64)] {
            v.push(Instance { family, n, seed, permuted: false });
        }
    }
    // extra seeds: meshes and power-law dominate the paper's hard set;
    // the two 80k instances exceed the 65 536-thread CT grid so the
    // CT-vs-MT contrast of Table 1 is exercised
    for (family, n, seed) in [
        (Family::Road, 80_000, 3),
        (Family::Delaunay, 16_000, 3),
        (Family::Kron, 80_000, 3),
        (Family::Social, 16_000, 3),
        (Family::Banded, 16_000, 3),
    ] {
        v.push(Instance { family, n: n * f, seed, permuted: false });
    }
    v
}

/// The RCP catalog (same instances, randomly permuted).
pub fn rcp(scale: Scale) -> Vec<Instance> {
    original(scale).into_iter().map(|i| i.rcp()).collect()
}

/// Look up an instance by its catalog name (both sets).
pub fn by_name(name: &str, scale: Scale) -> Option<Instance> {
    original(scale)
        .into_iter()
        .chain(rcp(scale))
        .find(|i| i.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_nonempty_and_distinct() {
        let v = original(Scale::Small);
        assert!(v.len() >= 25, "got {}", v.len());
        let names: std::collections::HashSet<_> = v.iter().map(|i| i.name()).collect();
        assert_eq!(names.len(), v.len());
    }

    #[test]
    fn rcp_mirrors_original() {
        let o = original(Scale::Small);
        let r = rcp(Scale::Small);
        assert_eq!(o.len(), r.len());
        assert!(r.iter().all(|i| i.permuted));
        assert!(r.iter().all(|i| i.name().ends_with("_rcp")));
    }

    #[test]
    fn build_smallest_instances() {
        // building every instance would be slow in tests; check one per
        // family at reduced size
        for family in Family::ALL {
            let i = Instance { family, n: 400, seed: 1, permuted: false };
            let g = i.build();
            assert!(g.validate().is_ok(), "{}", i.name());
            let p = i.rcp().build();
            assert_eq!(g.n_edges(), p.n_edges(), "{}", i.name());
        }
    }

    #[test]
    fn by_name_roundtrip() {
        let scale = Scale::Small;
        let inst = &original(scale)[0];
        assert_eq!(by_name(&inst.name(), scale), Some(*inst));
        assert_eq!(by_name(&inst.rcp().name(), scale), Some(inst.rcp()));
        assert!(by_name("nope", scale).is_none());
    }

    #[test]
    fn scale_changes_sizes() {
        let s = original(Scale::Small);
        let l = original(Scale::Large);
        assert_eq!(s.len(), l.len());
        assert!(l[0].n > s[0].n);
    }
}
