//! Evaluation runner with an on-disk result cache, so the figure benches
//! (Fig. 3/4/5) reuse the timing matrix the table benches (1/2) produce
//! instead of re-running a multi-minute sweep.
//!
//! A record = one (instance, algorithm) measurement: wall seconds after
//! the common cheap-matching initialization (exactly the paper's protocol,
//! §4), modeled device milliseconds for GPU variants, cardinality, and
//! phase counters. Cache lives in `target/bimatch_eval/<scale>.tsv`.

use super::catalog::{Instance, Scale};
use crate::coordinator::registry;
use crate::matching::algo::RunCtx;
use crate::matching::init::InitHeuristic;
use crate::util::timer::Timer;
use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;

#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub instance: String,
    pub algo: String,
    pub wall_secs: f64,
    /// serial-model device ms (CT/MT & kernel comparisons)
    pub device_ms: f64,
    /// parallel-model device ms (cross-hardware figures)
    pub device_parallel_ms: f64,
    pub cardinality: usize,
    pub phases: u64,
}

/// TSV round-trip (no serde offline).
impl Record {
    fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{:.9}\t{:.6}\t{:.6}\t{}\t{}",
            self.instance, self.algo, self.wall_secs, self.device_ms,
            self.device_parallel_ms, self.cardinality, self.phases
        )
    }

    fn from_line(line: &str) -> Option<Record> {
        let mut it = line.split('\t');
        Some(Record {
            instance: it.next()?.to_string(),
            algo: it.next()?.to_string(),
            wall_secs: it.next()?.parse().ok()?,
            device_ms: it.next()?.parse().ok()?,
            device_parallel_ms: it.next()?.parse().ok()?,
            cardinality: it.next()?.parse().ok()?,
            phases: it.next()?.parse().ok()?,
        })
    }
}

pub struct Evaluator {
    scale: Scale,
    cache_path: PathBuf,
    records: HashMap<(String, String), Record>,
    pub verify: bool,
}

impl Evaluator {
    pub fn new(scale: Scale) -> Self {
        let dir = PathBuf::from("target/bimatch_eval");
        let _ = std::fs::create_dir_all(&dir);
        let cache_path = dir.join(format!("{}.tsv", scale.name()));
        let mut records = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(&cache_path) {
            for line in text.lines() {
                if let Some(r) = Record::from_line(line) {
                    records.insert((r.instance.clone(), r.algo.clone()), r);
                }
            }
        }
        Self { scale, cache_path, records, verify: true }
    }

    pub fn scale(&self) -> Scale {
        self.scale
    }

    fn persist(&self, r: &Record) {
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.cache_path)
        {
            let _ = writeln!(f, "{}", r.to_line());
        }
    }

    /// Measure (or fetch from cache) one (instance, algo) cell. The graph
    /// and cheap init are rebuilt per call — only the matching phase is
    /// timed, matching the paper's protocol.
    pub fn measure(&mut self, inst: &Instance, algo_name: &str) -> Record {
        let key = (inst.name(), algo_name.to_string());
        if let Some(r) = self.records.get(&key) {
            return r.clone();
        }
        let g = inst.build();
        let init = InitHeuristic::Cheap.run(&g);
        let algo = registry::build_named(algo_name, None).unwrap_or_else(|e| panic!("{e}"));
        // every measured cell gets a FRESH context: sharing a workspace
        // pool across measurements would make wall-clock records
        // order-dependent (the first algorithm on a size pays all the
        // allocations, later ones run warm), biasing the paper tables
        let mut ctx = RunCtx::detached();
        let t = Timer::start();
        let result = algo.run(&g, init, &mut ctx);
        let wall = t.elapsed_secs();
        if self.verify {
            result
                .matching
                .certify(&g)
                .unwrap_or_else(|e| panic!("{algo_name} on {}: {e}", inst.name()));
        }
        let r = Record {
            instance: inst.name(),
            algo: algo_name.to_string(),
            wall_secs: wall,
            device_ms: result.stats.device_cycles as f64 / 1e6,
            device_parallel_ms: result.stats.device_parallel_cycles as f64 / 1e6,
            cardinality: result.matching.cardinality(),
            phases: result.stats.phases,
        };
        self.persist(&r);
        self.records.insert(key, r.clone());
        r
    }

    /// Measure a matrix: every algorithm on every instance.
    pub fn sweep(&mut self, instances: &[Instance], algos: &[&str]) -> Vec<Record> {
        let mut out = Vec::with_capacity(instances.len() * algos.len());
        for inst in instances {
            for algo in algos {
                out.push(self.measure(inst, algo));
            }
        }
        out
    }

    /// Cached record lookup without measuring.
    pub fn get(&self, instance: &str, algo: &str) -> Option<&Record> {
        self.records.get(&(instance.to_string(), algo.to_string()))
    }
}

/// Instance subsets mirroring the paper's O_S1 / O_Hardest20 construction:
/// rank instances by the *fastest sequential* time (HK vs PFP, as in §4)
/// and keep those above a threshold ("S1") or the hardest `k`.
pub struct Subsets {
    /// instance name → fastest sequential seconds
    pub seq_time: HashMap<String, f64>,
}

impl Subsets {
    pub fn compute(ev: &mut Evaluator, instances: &[Instance]) -> Self {
        let mut seq_time = HashMap::new();
        for inst in instances {
            let hk = ev.measure(inst, "hk").wall_secs;
            let pfp = ev.measure(inst, "pfp").wall_secs;
            seq_time.insert(inst.name(), hk.min(pfp));
        }
        Self { seq_time }
    }

    /// Instances whose fastest sequential time exceeds `thresh` seconds
    /// (the paper's "took more than one second" ⇒ scaled to this testbed).
    pub fn s1(&self, instances: &[Instance], thresh: f64) -> Vec<Instance> {
        instances
            .iter()
            .filter(|i| self.seq_time.get(&i.name()).copied().unwrap_or(0.0) > thresh)
            .copied()
            .collect()
    }

    /// The `k` instances with the largest fastest-sequential time.
    pub fn hardest(&self, instances: &[Instance], k: usize) -> Vec<Instance> {
        let mut v: Vec<Instance> = instances.to_vec();
        v.sort_by(|a, b| {
            let ta = self.seq_time.get(&a.name()).copied().unwrap_or(0.0);
            let tb = self.seq_time.get(&b.name()).copied().unwrap_or(0.0);
            tb.partial_cmp(&ta).unwrap()
        });
        v.truncate(k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::Family;

    fn tiny_instance() -> Instance {
        Instance { family: Family::Uniform, n: 400, seed: 9, permuted: false }
    }

    #[test]
    fn record_line_roundtrip() {
        let r = Record {
            instance: "x".into(),
            algo: "hk".into(),
            wall_secs: 0.125,
            device_ms: 3.5,
            device_parallel_ms: 0.2,
            cardinality: 42,
            phases: 7,
        };
        assert_eq!(Record::from_line(&r.to_line()), Some(r));
        assert_eq!(Record::from_line("garbage"), None);
    }

    #[test]
    fn measure_caches() {
        let mut ev = Evaluator::new(Scale::Small);
        let inst = tiny_instance();
        let a = ev.measure(&inst, "hk");
        let b = ev.measure(&inst, "hk");
        assert_eq!(a, b, "second call must come from cache");
        assert!(a.cardinality > 0);
    }

    #[test]
    fn sweep_and_subsets() {
        let mut ev = Evaluator::new(Scale::Small);
        let instances = vec![
            tiny_instance(),
            Instance { family: Family::Banded, n: 500, seed: 9, permuted: false },
        ];
        let recs = ev.sweep(&instances, &["hk", "pfp"]);
        assert_eq!(recs.len(), 4);
        let subs = Subsets::compute(&mut ev, &instances);
        assert_eq!(subs.seq_time.len(), 2);
        assert_eq!(subs.hardest(&instances, 1).len(), 1);
        // threshold 0 keeps everything with positive time
        assert_eq!(subs.s1(&instances, 0.0).len(), 2);
        assert!(subs.s1(&instances, 1e9).is_empty());
    }

    #[test]
    fn algorithms_agree_across_evaluator() {
        let mut ev = Evaluator::new(Scale::Small);
        let inst = tiny_instance();
        let cards: Vec<usize> = ["hk", "pfp", "gpu:APFB-GPUBFS-WR-CT"]
            .iter()
            .map(|a| ev.measure(&inst, a).cardinality)
            .collect();
        assert!(cards.windows(2).all(|w| w[0] == w[1]), "{cards:?}");
    }
}
