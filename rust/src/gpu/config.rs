//! Configuration of the eight GPU algorithm variants (§4 of the paper):
//! driver (APFB/APsB) × BFS kernel (GPUBFS/GPUBFS-WR) × thread mapping
//! (CT/MT).

/// Outer driver loop (Algorithm 1 and its no-early-exit variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ApDriver {
    /// "Augmenting Paths to the Full Bottom": keep expanding BFS levels
    /// until the frontier is exhausted (GPU analogue of HKDW).
    Apfb,
    /// "Shortest Augmenting Paths": break out of the BFS as soon as any
    /// augmenting path is found (GPU analogue of HK). Algorithm 1 verbatim.
    Apsb,
}

/// Single-level BFS kernel flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BfsKernel {
    /// Algorithm 2: plain level expansion.
    GpuBfs,
    /// Algorithm 4: carries `root` down the tree; trees whose root already
    /// has an augmenting path stop expanding (early exit).
    GpuBfsWr,
}

/// Thread→column assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ThreadMapping {
    /// Constant threads: fixed 256×256 grid, each thread owns
    /// ceil(nc / 65536) strided columns (coalesced).
    Ct,
    /// Max threads: one column per thread (min(nc, arch max)).
    Mt,
}

pub const CT_THREADS: usize = 256 * 256;
pub const WARP_SIZE: usize = 32;

impl ThreadMapping {
    /// Total thread count for a kernel over `n` items.
    pub fn total_threads(&self, n: usize) -> usize {
        match self {
            ThreadMapping::Ct => CT_THREADS,
            ThreadMapping::Mt => n.max(1),
        }
    }
}

/// How simultaneous conflicting writes are arbitrated by the simulator —
/// each order is one legal serialization of the CUDA race (DESIGN.md §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WriteOrder {
    /// ascending thread id (default; min-index winner on last write wins
    /// semantics corresponds to max-index... order of iteration)
    #[default]
    Forward,
    /// descending thread id
    Reverse,
    /// seeded pseudo-random interleaving
    Shuffled,
}

/// Full variant configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GpuConfig {
    pub driver: ApDriver,
    pub kernel: BfsKernel,
    pub mapping: ThreadMapping,
    pub write_order: WriteOrder,
    /// seed for `WriteOrder::Shuffled`
    pub seed: u64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        // the paper's overall winner: APFB + GPUBFS-WR + CT
        Self {
            driver: ApDriver::Apfb,
            kernel: BfsKernel::GpuBfsWr,
            mapping: ThreadMapping::Ct,
            write_order: WriteOrder::Forward,
            seed: 0,
        }
    }
}

impl GpuConfig {
    /// All eight paper variants (Table 1), default write order.
    pub fn all_variants() -> Vec<GpuConfig> {
        let mut out = Vec::with_capacity(8);
        for driver in [ApDriver::Apfb, ApDriver::Apsb] {
            for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                for mapping in [ThreadMapping::Mt, ThreadMapping::Ct] {
                    out.push(GpuConfig {
                        driver,
                        kernel,
                        mapping,
                        write_order: WriteOrder::Forward,
                        seed: 0,
                    });
                }
            }
        }
        out
    }

    /// Short name matching the paper's terminology, e.g.
    /// "APFB-GPUBFS-WR-CT".
    pub fn name(&self) -> String {
        format!(
            "{}-{}-{}",
            match self.driver {
                ApDriver::Apfb => "APFB",
                ApDriver::Apsb => "APsB",
            },
            match self.kernel {
                BfsKernel::GpuBfs => "GPUBFS",
                BfsKernel::GpuBfsWr => "GPUBFS-WR",
            },
            match self.mapping {
                ThreadMapping::Ct => "CT",
                ThreadMapping::Mt => "MT",
            }
        )
    }

    /// Parse "APFB-GPUBFS-WR-CT"-style names.
    pub fn from_name(s: &str) -> Option<GpuConfig> {
        GpuConfig::all_variants().into_iter().find(|c| c.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_distinct_variants() {
        let v = GpuConfig::all_variants();
        assert_eq!(v.len(), 8);
        let names: std::collections::HashSet<_> = v.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 8);
        assert!(names.contains("APFB-GPUBFS-WR-CT"));
        assert!(names.contains("APsB-GPUBFS-MT"));
    }

    #[test]
    fn name_roundtrip() {
        for c in GpuConfig::all_variants() {
            assert_eq!(GpuConfig::from_name(&c.name()), Some(c));
        }
        assert_eq!(GpuConfig::from_name("bogus"), None);
    }

    #[test]
    fn thread_counts() {
        assert_eq!(ThreadMapping::Ct.total_threads(10), CT_THREADS);
        assert_eq!(ThreadMapping::Mt.total_threads(10), 10);
        assert_eq!(ThreadMapping::Mt.total_threads(0), 1);
    }
}
