//! Configuration of the eight GPU algorithm variants (§4 of the paper):
//! driver (APFB/APsB) × BFS kernel (GPUBFS/GPUBFS-WR) × thread mapping
//! (CT/MT) — plus two execution knobs that are ours, not the paper's:
//! frontier compaction ([`FrontierMode`]) and host-side parallel kernel
//! execution (`device_parallelism`).

/// Outer driver loop (Algorithm 1 and its no-early-exit variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ApDriver {
    /// "Augmenting Paths to the Full Bottom": keep expanding BFS levels
    /// until the frontier is exhausted (GPU analogue of HKDW).
    Apfb,
    /// "Shortest Augmenting Paths": break out of the BFS as soon as any
    /// augmenting path is found (GPU analogue of HK). Algorithm 1 verbatim.
    Apsb,
}

/// Single-level BFS kernel flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BfsKernel {
    /// Algorithm 2: plain level expansion.
    GpuBfs,
    /// Algorithm 4: carries `root` down the tree; trees whose root already
    /// has an augmenting path stop expanding (early exit).
    GpuBfsWr,
}

/// Thread→column assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ThreadMapping {
    /// Constant threads: fixed 256×256 grid, each thread owns
    /// ceil(nc / 65536) strided columns (coalesced).
    Ct,
    /// Max threads: one column per thread (min(nc, arch max)).
    Mt,
}

pub const CT_THREADS: usize = 256 * 256;
pub const WARP_SIZE: usize = 32;

impl ThreadMapping {
    /// Total thread count for a kernel over `n` items.
    pub fn total_threads(&self, n: usize) -> usize {
        match self {
            ThreadMapping::Ct => CT_THREADS,
            ThreadMapping::Mt => n.max(1),
        }
    }
}

/// How simultaneous conflicting writes are arbitrated by the simulator —
/// each order is one legal serialization of the CUDA race (DESIGN.md §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WriteOrder {
    /// ascending thread id (default; min-index winner on last write wins
    /// semantics corresponds to max-index... order of iteration)
    #[default]
    Forward,
    /// descending thread id
    Reverse,
    /// seeded pseudo-random interleaving
    Shuffled,
}

/// How each kernel finds its live items — BFS sweeps *and* ALTERNATE.
///
/// The paper's kernels launch over *all* `nc` columns every level and let
/// inactive threads bail (`bfs_array[col] != bfs_level`), so a late level
/// with 3 live columns still pays an `O(nc)` scan — and ALTERNATE pays
/// the analogous `O(nr)` scan selecting its `-2` endpoint rows.
/// `Compacted` keeps explicit worklists instead: each sweep consumes the
/// current frontier and emits the next one (per-launch work
/// `O(|frontier| + edges(frontier))`), and the sweeps also emit the
/// endpoint worklist ALTERNATE consumes directly. `FullScan` stays the
/// `GpuConfig` default for paper-faithful reproduction runs — the
/// coordinator's router picks the `-FC` twin for auto-routed GPU work —
/// and both modes provably reach the same cardinality (see the property
/// tests in `gpu::driver`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FrontierMode {
    /// Paper-faithful: every kernel launch covers all `nc` columns.
    #[default]
    FullScan,
    /// Worklist-driven: launches cover only the live frontier, which each
    /// sweep compacts for the next level.
    Compacted,
    /// Per-phase switching: early phases (dense frontiers, where the
    /// worklist machinery only adds compaction overhead) run FullScan;
    /// once the phase-seed frontier density — unmatched columns over `nc`,
    /// the lower bound of what `RunStats::frontier_peak` would record —
    /// drops below `1/ADAPTIVE_DENSITY_DIV`, later phases run Compacted
    /// (sparse late frontiers are exactly where the `O(nc)` scan floor
    /// hurts). Ablated in `bench_frontier`.
    Adaptive,
}

/// [`FrontierMode::Adaptive`] switch threshold: a phase runs Compacted
/// when `unmatched_columns * ADAPTIVE_DENSITY_DIV < nc` (frontier density
/// below 1/8), FullScan otherwise.
pub const ADAPTIVE_DENSITY_DIV: usize = 8;

impl FrontierMode {
    pub fn name(&self) -> &'static str {
        match self {
            FrontierMode::FullScan => "fullscan",
            FrontierMode::Compacted => "compacted",
            FrontierMode::Adaptive => "adaptive",
        }
    }

    pub fn from_name(s: &str) -> Option<FrontierMode> {
        match s {
            "fullscan" | "full" => Some(FrontierMode::FullScan),
            "compacted" | "frontier" => Some(FrontierMode::Compacted),
            "adaptive" | "auto" => Some(FrontierMode::Adaptive),
            _ => None,
        }
    }
}

/// Full variant configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GpuConfig {
    pub driver: ApDriver,
    pub kernel: BfsKernel,
    pub mapping: ThreadMapping,
    pub write_order: WriteOrder,
    /// seed for `WriteOrder::Shuffled`
    pub seed: u64,
    /// full-scan (paper) vs worklist-compacted kernels (BFS + ALTERNATE)
    pub frontier: FrontierMode,
    /// host threads executing the simulator's kernels; 1 = serial. The
    /// per-item-disjoint kernels (INITBFSARRAY, FIXMATCHING) keep
    /// identical results and modeled cycles at any value; the racy ones
    /// (BFS sweeps, ALTERNATE) run through the atomic CAS substrate —
    /// claim winners follow the host schedule (one legal serialization of
    /// the CUDA race) and modeled cycles gain the CAS charges, while the
    /// final matching cardinality stays schedule-independent
    /// (property-tested in `gpu::driver`).
    pub device_parallelism: usize,
}

impl Default for GpuConfig {
    fn default() -> Self {
        // the paper's overall winner: APFB + GPUBFS-WR + CT
        Self {
            driver: ApDriver::Apfb,
            kernel: BfsKernel::GpuBfsWr,
            mapping: ThreadMapping::Ct,
            write_order: WriteOrder::Forward,
            seed: 0,
            frontier: FrontierMode::FullScan,
            device_parallelism: 1,
        }
    }
}

impl GpuConfig {
    /// All eight paper variants (Table 1), default write order.
    pub fn all_variants() -> Vec<GpuConfig> {
        let mut out = Vec::with_capacity(8);
        for driver in [ApDriver::Apfb, ApDriver::Apsb] {
            for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                for mapping in [ThreadMapping::Mt, ThreadMapping::Ct] {
                    out.push(GpuConfig {
                        driver,
                        kernel,
                        mapping,
                        ..GpuConfig::default()
                    });
                }
            }
        }
        out
    }

    /// The eight paper variants plus their frontier-compacted twins (16).
    pub fn all_variants_with_frontier() -> Vec<GpuConfig> {
        let mut out = GpuConfig::all_variants();
        for base in GpuConfig::all_variants() {
            out.push(GpuConfig { frontier: FrontierMode::Compacted, ..base });
        }
        out
    }

    /// This configuration with frontier compaction enabled.
    pub fn compacted(self) -> GpuConfig {
        GpuConfig { frontier: FrontierMode::Compacted, ..self }
    }

    /// This configuration with per-phase adaptive frontier switching.
    pub fn adaptive(self) -> GpuConfig {
        GpuConfig { frontier: FrontierMode::Adaptive, ..self }
    }

    /// Effective host-thread count for the simulator's kernels (disjoint
    /// *and* racy — see `device_parallelism`): an explicit
    /// `device_parallelism > 1` wins; otherwise the `BIMATCH_DEVICE_PAR`
    /// environment variable supplies the default, so registry-built
    /// matchers (CLI, server, harness) can opt in without new names.
    /// Falls back to 1 (serial).
    pub fn effective_device_parallelism(&self) -> usize {
        if self.device_parallelism > 1 {
            return self.device_parallelism;
        }
        std::env::var("BIMATCH_DEVICE_PAR")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1)
    }

    /// Short name matching the paper's terminology, e.g.
    /// "APFB-GPUBFS-WR-CT"; frontier-compacted variants carry an "-FC"
    /// suffix ("APFB-GPUBFS-WR-CT-FC").
    pub fn name(&self) -> String {
        format!(
            "{}-{}-{}{}",
            match self.driver {
                ApDriver::Apfb => "APFB",
                ApDriver::Apsb => "APsB",
            },
            match self.kernel {
                BfsKernel::GpuBfs => "GPUBFS",
                BfsKernel::GpuBfsWr => "GPUBFS-WR",
            },
            match self.mapping {
                ThreadMapping::Ct => "CT",
                ThreadMapping::Mt => "MT",
            },
            match self.frontier {
                FrontierMode::FullScan => "",
                FrontierMode::Compacted => "-FC",
                FrontierMode::Adaptive => "-AF",
            }
        )
    }

    /// Parse "APFB-GPUBFS-WR-CT"-style names (with optional "-FC"/"-AF"
    /// suffix): the exact inverse of [`GpuConfig::name`], resolved against
    /// the 16 registered variants plus the eight adaptive twins — no
    /// suffix surgery.
    pub fn from_name(s: &str) -> Option<GpuConfig> {
        GpuConfig::all_variants_with_frontier()
            .into_iter()
            .chain(GpuConfig::all_variants().into_iter().map(GpuConfig::adaptive))
            .find(|c| c.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_distinct_variants() {
        let v = GpuConfig::all_variants();
        assert_eq!(v.len(), 8);
        let names: std::collections::HashSet<_> = v.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 8);
        assert!(names.contains("APFB-GPUBFS-WR-CT"));
        assert!(names.contains("APsB-GPUBFS-MT"));
    }

    #[test]
    fn name_roundtrip() {
        for c in GpuConfig::all_variants() {
            assert_eq!(GpuConfig::from_name(&c.name()), Some(c));
        }
        assert_eq!(GpuConfig::from_name("bogus"), None);
    }

    #[test]
    fn frontier_variants_roundtrip() {
        let v = GpuConfig::all_variants_with_frontier();
        assert_eq!(v.len(), 16);
        let names: std::collections::HashSet<_> = v.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), 16);
        assert!(names.contains("APFB-GPUBFS-WR-CT-FC"));
        for c in v {
            assert_eq!(GpuConfig::from_name(&c.name()), Some(c));
        }
        assert_eq!(
            GpuConfig::from_name("APFB-GPUBFS-WR-CT-FC").unwrap().frontier,
            FrontierMode::Compacted
        );
        assert_eq!(GpuConfig::from_name("bogus-FC"), None);
    }

    #[test]
    fn frontier_mode_names() {
        for m in [FrontierMode::FullScan, FrontierMode::Compacted, FrontierMode::Adaptive] {
            assert_eq!(FrontierMode::from_name(m.name()), Some(m));
        }
        assert_eq!(FrontierMode::from_name("frontier"), Some(FrontierMode::Compacted));
        assert_eq!(FrontierMode::from_name("auto"), Some(FrontierMode::Adaptive));
        assert_eq!(FrontierMode::from_name("nope"), None);
        assert_eq!(FrontierMode::default(), FrontierMode::FullScan);
    }

    #[test]
    fn adaptive_variants_roundtrip_but_stay_out_of_the_registry_set() {
        let c = GpuConfig::default().adaptive();
        assert_eq!(c.name(), "APFB-GPUBFS-WR-CT-AF");
        assert_eq!(GpuConfig::from_name("APFB-GPUBFS-WR-CT-AF"), Some(c));
        for base in GpuConfig::all_variants() {
            let a = base.adaptive();
            assert_eq!(GpuConfig::from_name(&a.name()), Some(a));
        }
        // the 16 registered variants are fullscan/compacted only
        assert!(GpuConfig::all_variants_with_frontier()
            .iter()
            .all(|c| c.frontier != FrontierMode::Adaptive));
    }

    #[test]
    fn compacted_helper_only_touches_frontier() {
        let c = GpuConfig::default().compacted();
        assert_eq!(c.frontier, FrontierMode::Compacted);
        assert_eq!(c.driver, GpuConfig::default().driver);
        assert_eq!(c.name(), "APFB-GPUBFS-WR-CT-FC");
    }

    #[test]
    fn effective_device_parallelism_prefers_explicit_config() {
        // (the BIMATCH_DEVICE_PAR env path is exercised manually — setting
        // env vars inside the threaded test runner races other tests, and
        // the default-branch assertion only holds when the var is unset)
        if std::env::var("BIMATCH_DEVICE_PAR").is_err() {
            assert_eq!(GpuConfig::default().effective_device_parallelism(), 1);
        }
        let c = GpuConfig { device_parallelism: 4, ..Default::default() };
        assert_eq!(c.effective_device_parallelism(), 4);
    }

    #[test]
    fn thread_counts() {
        assert_eq!(ThreadMapping::Ct.total_threads(10), CT_THREADS);
        assert_eq!(ThreadMapping::Mt.total_threads(10), 10);
        assert_eq!(ThreadMapping::Mt.total_threads(0), 1);
    }
}
