//! The outer driver loops (paper Algorithm 1 and its APFB variant) tying
//! the kernels together, exposed through the common
//! [`MatchingAlgorithm`] interface as [`GpuMatcher`].
//!
//! Two execution-mode knobs ride on top of the paper's eight variants:
//! * [`FrontierMode::Compacted`] swaps the full-`nc` BFS sweeps for
//!   worklist-driven ones (`gpubfs_frontier`/`gpubfs_wr_frontier`) *and*
//!   hands ALTERNATE the endpoint worklist those sweeps emit, skipping
//!   the all-rows selection scan. The driver owns both worklist
//!   lifecycles — built/cleared each phase, consumed per level (frontier)
//!   or per phase (endpoints). `RunStats::{frontier_peak, frontier_total,
//!   endpoints_total}` record what the worklists saved.
//! * `GpuConfig::device_parallelism` executes *every* kernel on host
//!   threads: the per-item-disjoint ones with unchanged results and
//!   cycles, the racy ones (BFS sweeps, ALTERNATE) through the atomic
//!   CAS substrate in `gpu::device` — claim winners follow the host
//!   schedule (one legal serialization of the CUDA race), modeled cycles
//!   gain the CAS charges, and the final cardinality is
//!   schedule-independent (property-tested against serial).
//!
//! The matching cardinality is maintained incrementally (seeded from the
//! initial matching, updated from FIXMATCHING's piggybacked count and the
//! safety net) instead of the former two `O(nc)` scans per phase.

use super::config::{ApDriver, BfsKernel, FrontierMode, GpuConfig};
use super::device::{charge_frontier_scan, charge_uniform_scan, DeviceClock};
use super::kernels::{
    alternate, fixmatching, gpubfs, gpubfs_frontier, gpubfs_wr, gpubfs_wr_frontier,
    init_bfs_array, init_bfs_array_frontier, init_bfs_array_seeded, wr_chosen_endpoints,
    wr_chosen_endpoints_from, GpuState, LaunchCfg, L0,
};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult};
use crate::matching::{Matching, UNMATCHED};

/// One of the eight paper variants as a ready-to-run matcher.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuMatcher {
    pub config: GpuConfig,
}

impl GpuMatcher {
    pub fn new(config: GpuConfig) -> Self {
        Self { config }
    }

    /// Run and also return the device clock (for the modeled-time tables).
    /// Device arrays and worklists are leased from `ctx`'s workspace pool;
    /// the deadline/cancellation checkpoint sits at the top of each phase
    /// (between kernel sequences, never inside one).
    pub fn run_with_clock(
        &self,
        g: &BipartiteCsr,
        init: Matching,
        ctx: &mut RunCtx,
    ) -> (RunResult, DeviceClock) {
        self.run_with_clock_impl(g, init, None, ctx)
    }

    /// The incremental-repair entry point (`dynamic::repair`): the *first*
    /// phase's BFS starts only from `seeds` — the columns a delta batch
    /// exposed — instead of every unmatched column, so a small update
    /// explores `O(reachable-from-seeds)` rather than the whole residual
    /// structure. Under [`FrontierMode::Compacted`] the seed set *is* the
    /// initial frontier worklist; under FullScan the non-seed columns are
    /// simply left dormant at `L0 - 1`. Every later phase reverts to the
    /// full unmatched-column start, and a quiet seeded phase does not end
    /// the run (it proves nothing about columns outside the seed set), so
    /// the returned matching carries the same maximality guarantee as
    /// [`MatchingAlgorithm::run`].
    pub fn run_repair_with_clock(
        &self,
        g: &BipartiteCsr,
        init: Matching,
        seeds: &[u32],
        ctx: &mut RunCtx,
    ) -> (RunResult, DeviceClock) {
        self.run_with_clock_impl(g, init, Some(seeds), ctx)
    }

    fn run_with_clock_impl(
        &self,
        g: &BipartiteCsr,
        init: Matching,
        seeds: Option<&[u32]>,
        ctx: &mut RunCtx,
    ) -> (RunResult, DeviceClock) {
        let cfg = LaunchCfg {
            mapping: self.config.mapping,
            order: self.config.write_order,
            seed: self.config.seed,
            par_threads: self.config.effective_device_parallelism(),
        };
        let with_root = self.config.kernel == BfsKernel::GpuBfsWr;
        // the APsB-GPUBFS-WR improvement (endpoint encoding + restricted
        // ALTERNATE) — the paper enables it only for that combination
        let improved_wr = with_root && self.config.driver == ApDriver::Apsb;
        // Adaptive leases the worklists up front (its late phases compact)
        // and decides FullScan vs Compacted per phase below.
        let uses_worklists = self.config.frontier != FrontierMode::FullScan;

        let mut state = GpuState::new_in(g, &init, ctx.pool());
        let mut clock = DeviceClock::default();
        // Incrementally maintained |M|: seeded once from the initial
        // matching, then updated from FIXMATCHING's piggybacked count and
        // the safety net — no per-phase O(nc) scans.
        let mut cardinality = init.cardinality();
        // worklists live only in Compacted mode: lease size-fitted buffers
        // there (frontier/next bounded by nc; the endpoint list — the rows
        // flagged `-2` that the compacted ALTERNATE consumes — by nr), and
        // keep FullScan runs off the pool entirely so they neither pop
        // shelved buffers they never push to nor inflate reuses()
        let (mut frontier, mut next_frontier, mut endpoints) = if uses_worklists {
            (
                ctx.lease_worklist_u32(g.nc),
                ctx.lease_worklist_u32(g.nc),
                ctx.lease_worklist_u32(g.nr),
            )
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let mut outcome = RunOutcome::Complete;
        // seeded first phase (repair path): taken exactly once
        let mut pending_seeds = seeds;

        loop {
            // checkpoint at the phase boundary: the state is sentinel-free
            // here, so an interrupted run still hands back a valid matching
            if let Some(trip) = ctx.checkpoint() {
                outcome = trip;
                break;
            }
            // ---- one phase: combined BFS over all unmatched columns, or
            // over the repair seed set on the first phase of a seeded run
            let seeded_phase = pending_seeds.is_some();
            // per-phase frontier mode: Adaptive starts FullScan while the
            // phase-seed frontier (the unmatched columns) is dense and
            // flips to Compacted once its density drops below the
            // threshold — dense phases skip the compaction overhead,
            // sparse phases skip the O(nc)/O(nr) scan floors
            let compacted = match self.config.frontier {
                FrontierMode::FullScan => false,
                FrontierMode::Compacted => true,
                FrontierMode::Adaptive => {
                    (g.nc - cardinality) * super::config::ADAPTIVE_DENSITY_DIV < g.nc
                }
            };
            let init_cycles0 = clock.cycles;
            if let Some(s) = pending_seeds.take() {
                init_bfs_array_seeded(
                    &mut state,
                    cfg,
                    with_root,
                    s,
                    compacted.then_some(&mut frontier),
                    &mut clock,
                );
                if compacted {
                    endpoints.clear();
                }
            } else if compacted {
                init_bfs_array_frontier(&mut state, cfg, with_root, &mut frontier, &mut clock);
                endpoints.clear();
            } else {
                init_bfs_array(&mut state, cfg, with_root, &mut clock);
            }
            let init_dur = clock.cycles - init_cycles0;
            if let Some(t) = ctx.trace() {
                t.device_span(
                    "init_bfs_array",
                    "kernel",
                    0,
                    init_cycles0,
                    init_dur,
                    vec![("seeded", seeded_phase as u64)],
                );
            }
            state.augmenting_path_found = false;
            let mut bfs_level = L0;
            let mut launches = 0u32;
            loop {
                state.vertex_inserted = false;
                let kernel_cycles0 = clock.cycles;
                let frontier_len = frontier.len() as u64;
                let scanned = if compacted {
                    ctx.stats.frontier_total += frontier.len() as u64;
                    ctx.stats.frontier_peak =
                        ctx.stats.frontier_peak.max(frontier.len() as u64);
                    next_frontier.clear();
                    match self.config.kernel {
                        BfsKernel::GpuBfs => gpubfs_frontier(
                            g,
                            &mut state,
                            bfs_level,
                            &frontier,
                            &mut next_frontier,
                            &mut endpoints,
                            cfg,
                            &mut clock,
                        ),
                        BfsKernel::GpuBfsWr => gpubfs_wr_frontier(
                            g,
                            &mut state,
                            bfs_level,
                            &frontier,
                            &mut next_frontier,
                            &mut endpoints,
                            cfg,
                            improved_wr,
                            &mut clock,
                        ),
                    }
                } else {
                    match self.config.kernel {
                        BfsKernel::GpuBfs => gpubfs(g, &mut state, bfs_level, cfg, &mut clock),
                        BfsKernel::GpuBfsWr => {
                            gpubfs_wr(g, &mut state, bfs_level, cfg, improved_wr, &mut clock)
                        }
                    }
                };
                ctx.stats.edges_scanned += scanned;
                launches += 1;
                if let Some(t) = ctx.trace() {
                    let name: &'static str = match (compacted, self.config.kernel) {
                        (true, BfsKernel::GpuBfs) => "gpubfs_frontier",
                        (true, BfsKernel::GpuBfsWr) => "gpubfs_wr_frontier",
                        (false, BfsKernel::GpuBfs) => "gpubfs",
                        (false, BfsKernel::GpuBfsWr) => "gpubfs_wr",
                    };
                    let mut args = vec![
                        ("level", (bfs_level - L0) as u64),
                        ("edges_scanned", scanned),
                    ];
                    if compacted {
                        args.push(("frontier", frontier_len));
                    }
                    t.device_span(name, "kernel", 0, kernel_cycles0, clock.cycles - kernel_cycles0, args);
                }
                // Algorithm 1 lines 8–10: APsB stops at the first level
                // with an augmenting path; APFB keeps going to the bottom.
                if self.config.driver == ApDriver::Apsb && state.augmenting_path_found {
                    break;
                }
                if !state.vertex_inserted {
                    break;
                }
                if compacted {
                    std::mem::swap(&mut frontier, &mut next_frontier);
                }
                bfs_level += 1;
            }
            ctx.record_phase(launches);
            if !state.augmenting_path_found {
                if seeded_phase {
                    // a quiet *seeded* phase only proves the seeds have no
                    // augmenting path — fall through to a full phase, which
                    // alone can certify global maximality (Berge)
                    continue;
                }
                break; // Berge: no augmenting path ⇒ maximum
            }

            // ---- speculative augmentation + repair ----
            let before = cardinality;
            if compacted {
                ctx.stats.endpoints_total += endpoints.len() as u64;
            }
            let alt_cycles0 = clock.cycles;
            if improved_wr {
                let chosen = if compacted {
                    // filter the endpoint worklist instead of scanning
                    // all nr rows — charged under the same warp model as
                    // the FullScan selection so the two branches stay
                    // comparable in both cycle views
                    charge_frontier_scan(&mut clock, cfg.mapping, endpoints.len());
                    wr_chosen_endpoints_from(&state, &endpoints)
                } else {
                    charge_uniform_scan(&mut clock, cfg.mapping, g.nr);
                    wr_chosen_endpoints(&state)
                };
                alternate(&mut state, cfg, Some(chosen.as_slice()), &mut clock);
            } else if compacted {
                alternate(&mut state, cfg, Some(endpoints.as_slice()), &mut clock);
            } else {
                alternate(&mut state, cfg, None, &mut clock);
            }
            let alt_dur = clock.cycles - alt_cycles0;
            if let Some(t) = ctx.trace() {
                t.device_span(
                    "alternate",
                    "kernel",
                    0,
                    alt_cycles0,
                    alt_dur,
                    vec![("endpoints", endpoints.len() as u64)],
                );
            }
            let fix_cycles0 = clock.cycles;
            let (fixes, after) = fixmatching(&mut state, cfg, &mut clock);
            let fix_dur = clock.cycles - fix_cycles0;
            if let Some(t) = ctx.trace() {
                t.device_span("fixmatching", "kernel", 0, fix_cycles0, fix_dur, vec![("fixes", fixes)]);
            }
            ctx.stats.fixes += fixes;
            let after = after as usize;
            debug_assert_eq!(after, state.cardinality(), "incremental |M| diverged");
            cardinality = after;
            ctx.stats.augmentations += after.saturating_sub(before) as u64;

            // Safety net (not in the paper, which relies on favorable
            // schedules): if this phase's speculative alternation made no
            // net progress, realize one augmenting path sequentially so
            // the outer loop provably terminates.
            if after <= before {
                if augment_one_sequential(g, &mut state) {
                    ctx.stats.fallbacks += 1;
                    ctx.stats.augmentations += 1;
                    cardinality += 1;
                } else {
                    break; // no augmenting path actually remains
                }
            }
        }

        ctx.stats.device_cycles += clock.cycles;
        ctx.stats.device_parallel_cycles += clock.parallel_cycles;
        if uses_worklists {
            ctx.give_u32(frontier);
            ctx.give_u32(next_frontier);
            ctx.give_u32(endpoints);
        }
        let m = state.release(ctx.pool());
        (ctx.finish_with(m, outcome), clock)
    }
}

impl GpuMatcher {
    /// [`GpuMatcher::run_repair_with_clock`] without the clock.
    pub fn run_repair(
        &self,
        g: &BipartiteCsr,
        init: Matching,
        seeds: &[u32],
        ctx: &mut RunCtx,
    ) -> RunResult {
        self.run_repair_with_clock(g, init, seeds, ctx).0
    }
}

impl MatchingAlgorithm for GpuMatcher {
    fn name(&self) -> String {
        format!("gpu:{}", self.config.name())
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        self.run_with_clock(g, init, ctx).0
    }
}

/// Host-side single BFS augmentation used by the no-progress safety net
/// (this driver's and the sharded driver's, `crate::shard`). Finds and
/// flips one shortest augmenting path.
pub(crate) fn augment_one_sequential(g: &BipartiteCsr, state: &mut GpuState) -> bool {
    let nr = state.rmatch.len();
    let nc = state.cmatch.len();
    let mut pred = vec![-1i32; nr];
    let mut cvis = vec![false; nc];
    let mut rvis = vec![false; nr];
    let mut frontier: Vec<u32> = Vec::new();
    for c in 0..nc {
        if state.cmatch[c] == UNMATCHED && g.col_degree(c) > 0 {
            cvis[c] = true;
            frontier.push(c as u32);
        }
    }
    let mut next = Vec::new();
    let mut endpoint = None;
    'outer: while !frontier.is_empty() {
        for &c in &frontier {
            for &r in g.col_neighbors(c as usize) {
                let r = r as usize;
                if rvis[r] {
                    continue;
                }
                rvis[r] = true;
                pred[r] = c as i32;
                match state.rmatch[r] {
                    UNMATCHED => {
                        endpoint = Some(r);
                        break 'outer;
                    }
                    c2 if c2 >= 0 => {
                        let c2 = c2 as usize;
                        if !cvis[c2] {
                            cvis[c2] = true;
                            next.push(c2 as u32);
                        }
                    }
                    _ => unreachable!("sentinel after fixmatching"),
                }
            }
        }
        std::mem::swap(&mut frontier, &mut next);
        next.clear();
    }
    let Some(mut r) = endpoint else { return false };
    loop {
        let c = pred[r] as usize;
        let prev = state.cmatch[c];
        state.cmatch[c] = r as i32;
        state.rmatch[r] = c as i32;
        if prev == UNMATCHED {
            return true;
        }
        r = prev as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::config::WriteOrder;
    use crate::graph::from_edges;
    use crate::matching::init::InitHeuristic;
    use crate::matching::reference_max_cardinality;
    use crate::util::qcheck::{arb_bipartite, forall, Config};

    #[test]
    fn all_eight_variants_small_graph() {
        let g = from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        for cfg in GpuConfig::all_variants() {
            let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(3, 3));
            r.matching
                .certify(&g)
                .unwrap_or_else(|e| panic!("{}: {e}", cfg.name()));
            assert_eq!(r.matching.cardinality(), 3, "{}", cfg.name());
        }
    }

    #[test]
    fn prop_all_variants_match_reference() {
        forall(Config::cases(12), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let want = reference_max_cardinality(&g);
            for cfg in GpuConfig::all_variants() {
                let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(nr, nc));
                r.matching
                    .certify(&g)
                    .map_err(|e| format!("{}: {e}", cfg.name()))?;
                if r.matching.cardinality() != want {
                    return Err(format!(
                        "{}: {} != {want}",
                        cfg.name(),
                        r.matching.cardinality()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_write_orders_all_valid() {
        forall(Config::cases(10), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 20);
            let g = from_edges(nr, nc, &edges);
            let want = reference_max_cardinality(&g);
            for order in [WriteOrder::Forward, WriteOrder::Reverse, WriteOrder::Shuffled] {
                let cfg = GpuConfig { write_order: order, seed: rng.next_u64(), ..Default::default() };
                let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(nr, nc));
                r.matching.certify(&g).map_err(|e| format!("{order:?}: {e}"))?;
                if r.matching.cardinality() != want {
                    return Err(format!("{order:?} suboptimal"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn variants_on_generated_families_with_init() {
        for fam in [
            crate::graph::gen::Family::Road,
            crate::graph::gen::Family::Kron,
            crate::graph::gen::Family::Banded,
        ] {
            let g = fam.generate(600, 17);
            let want = reference_max_cardinality(&g);
            let init = InitHeuristic::Cheap.run(&g);
            for cfg in GpuConfig::all_variants() {
                let r = GpuMatcher::new(cfg).run_detached(&g, init.clone());
                r.matching
                    .certify(&g)
                    .unwrap_or_else(|e| panic!("{} on {}: {e}", cfg.name(), fam.name()));
                assert_eq!(r.matching.cardinality(), want, "{} on {}", cfg.name(), fam.name());
            }
        }
    }

    #[test]
    fn apsb_records_more_phases_fewer_levels_per_phase() {
        // APsB stops each phase at the first augmenting level, so its
        // launches-per-phase must not exceed APFB's on the same graph.
        let g = crate::graph::gen::Family::Delaunay.generate(900, 23);
        let init = InitHeuristic::Cheap.run(&g);
        let apfb = GpuMatcher::new(GpuConfig {
            driver: ApDriver::Apfb,
            ..Default::default()
        })
        .run_detached(&g, init.clone());
        let apsb = GpuMatcher::new(GpuConfig {
            driver: ApDriver::Apsb,
            ..Default::default()
        })
        .run_detached(&g, init);
        assert!(apsb.stats.phases >= apfb.stats.phases);
        let max_apsb = apsb.stats.launches_per_phase.iter().max().copied().unwrap_or(0);
        let max_apfb = apfb.stats.launches_per_phase.iter().max().copied().unwrap_or(0);
        assert!(max_apsb <= max_apfb);
    }

    #[test]
    fn prop_frontier_modes_reach_reference_cardinality() {
        // FullScan and Compacted must agree (with the reference oracle) on
        // random bipartite graphs, for both drivers and both kernels.
        forall(Config::cases(10), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let want = reference_max_cardinality(&g);
            for driver in [ApDriver::Apfb, ApDriver::Apsb] {
                for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                    for frontier in [FrontierMode::FullScan, FrontierMode::Compacted] {
                        let cfg = GpuConfig { driver, kernel, frontier, ..Default::default() };
                        let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(nr, nc));
                        r.matching
                            .certify(&g)
                            .map_err(|e| format!("{}: {e}", cfg.name()))?;
                        if r.matching.cardinality() != want {
                            return Err(format!(
                                "{}: {} != {want}",
                                cfg.name(),
                                r.matching.cardinality()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn frontier_modes_agree_on_all_generator_families() {
        for fam in crate::graph::gen::Family::ALL {
            let g = fam.generate(500, 11);
            let init = InitHeuristic::Cheap.run(&g);
            let want = reference_max_cardinality(&g);
            for driver in [ApDriver::Apfb, ApDriver::Apsb] {
                let base = GpuConfig { driver, ..Default::default() };
                for cfg in [base, base.compacted()] {
                    let r = GpuMatcher::new(cfg).run_detached(&g, init.clone());
                    r.matching
                        .certify(&g)
                        .unwrap_or_else(|e| panic!("{} on {}: {e}", cfg.name(), fam.name()));
                    assert_eq!(
                        r.matching.cardinality(),
                        want,
                        "{} on {}",
                        cfg.name(),
                        fam.name()
                    );
                }
            }
        }
    }

    #[test]
    fn compacted_reduces_scan_cost_on_sparse_family() {
        // sparse road mesh: late BFS levels carry a handful of live
        // columns, exactly where the O(nc) full-scan floor hurts
        let g = crate::graph::gen::Family::Road.generate(4000, 7);
        let init = InitHeuristic::Cheap.run(&g);
        let full = GpuMatcher::default().run_detached(&g, init.clone());
        let fc = GpuMatcher::new(GpuConfig::default().compacted()).run_detached(&g, init);
        assert_eq!(full.matching.cardinality(), fc.matching.cardinality());
        assert!(fc.stats.frontier_peak > 0);
        assert!(fc.stats.frontier_peak <= g.nc as u64);
        assert!(fc.stats.frontier_total >= fc.stats.frontier_peak);
        assert!(fc.stats.endpoints_total > 0, "compacted ALTERNATE must consume the worklist");
        assert_eq!(full.stats.frontier_peak, 0, "FullScan must not report frontiers");
        assert_eq!(full.stats.frontier_total, 0);
        assert_eq!(full.stats.endpoints_total, 0);
        assert!(
            fc.stats.device_cycles < full.stats.device_cycles,
            "compacted {} must undercut full scan {}",
            fc.stats.device_cycles,
            full.stats.device_cycles
        );
        assert!(fc.stats.device_parallel_cycles < full.stats.device_parallel_cycles);
    }

    #[test]
    fn adaptive_mode_reaches_reference_on_all_families() {
        for fam in crate::graph::gen::Family::ALL {
            let g = fam.generate(400, 13);
            let want = reference_max_cardinality(&g);
            for driver in [ApDriver::Apfb, ApDriver::Apsb] {
                let cfg = GpuConfig { driver, ..Default::default() }.adaptive();
                let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(g.nr, g.nc));
                r.matching
                    .certify(&g)
                    .unwrap_or_else(|e| panic!("{} on {}: {e}", cfg.name(), fam.name()));
                assert_eq!(r.matching.cardinality(), want, "{} on {}", cfg.name(), fam.name());
            }
        }
    }

    #[test]
    fn prop_adaptive_matches_reference() {
        forall(Config::cases(10), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 25);
            let g = from_edges(nr, nc, &edges);
            let want = reference_max_cardinality(&g);
            for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                let cfg = GpuConfig { kernel, ..Default::default() }.adaptive();
                let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(nr, nc));
                r.matching.certify(&g).map_err(|e| format!("{}: {e}", cfg.name()))?;
                if r.matching.cardinality() != want {
                    return Err(format!("{}: {} != {want}", cfg.name(), r.matching.cardinality()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn adaptive_runs_fullscan_dense_phases_then_compacts() {
        // empty init: the first phase sees density 1.0 (all columns
        // unmatched) and must run FullScan; once the cheap bulk is matched
        // later sparse phases flip to Compacted and record frontiers
        let g = crate::graph::gen::Family::Road.generate(3000, 7);
        let af = GpuMatcher::new(GpuConfig::default().adaptive())
            .run_detached(&g, Matching::empty(g.nr, g.nc));
        af.matching.certify(&g).unwrap();
        assert!(af.stats.phases >= 2, "road needs repair phases");
        assert!(
            af.stats.frontier_peak > 0,
            "late sparse phases must have flipped to Compacted"
        );
        // a pure Compacted run records the dense first phase (every
        // column unmatched ⇒ frontier ≈ nc); adaptive ran that phase
        // FullScan, so its recorded peak must sit strictly below
        let fc = GpuMatcher::new(GpuConfig::default().compacted())
            .run_detached(&g, Matching::empty(g.nr, g.nc));
        assert_eq!(af.matching.cardinality(), fc.matching.cardinality());
        assert!(
            af.stats.frontier_peak < fc.stats.frontier_peak,
            "adaptive peak {} must undercut compacted peak {}",
            af.stats.frontier_peak,
            fc.stats.frontier_peak
        );
    }

    #[test]
    fn device_parallelism_preserves_cardinality_all_modes() {
        // the atomic path may pick different claim winners (and pays the
        // CAS charges), but the cardinality it reaches must match serial
        // for every driver × kernel × frontier mode
        let g = crate::graph::gen::Family::Banded.generate(800, 3);
        let init = InitHeuristic::Cheap.run(&g);
        for driver in [ApDriver::Apfb, ApDriver::Apsb] {
            for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                for frontier in [FrontierMode::FullScan, FrontierMode::Compacted] {
                    let base = GpuConfig { driver, kernel, frontier, ..Default::default() };
                    let serial = GpuMatcher::new(base).run_detached(&g, init.clone());
                    let par = GpuMatcher::new(GpuConfig { device_parallelism: 4, ..base })
                        .run_detached(&g, init.clone());
                    par.matching
                        .certify(&g)
                        .unwrap_or_else(|e| panic!("{} parallel: {e}", base.name()));
                    assert_eq!(
                        serial.matching.cardinality(),
                        par.matching.cardinality(),
                        "{} serial vs parallel",
                        base.name()
                    );
                }
            }
        }
    }

    #[test]
    fn prop_parallel_equals_serial_cardinality_every_variant() {
        // the tentpole qcheck: parallel ≡ serial cardinality for every
        // driver × kernel × frontier mode on random bipartite graphs
        forall(Config::cases(8), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 22);
            let g = from_edges(nr, nc, &edges);
            for driver in [ApDriver::Apfb, ApDriver::Apsb] {
                for kernel in [BfsKernel::GpuBfs, BfsKernel::GpuBfsWr] {
                    for frontier in [FrontierMode::FullScan, FrontierMode::Compacted] {
                        let base = GpuConfig { driver, kernel, frontier, ..Default::default() };
                        let s = GpuMatcher::new(base).run_detached(&g, Matching::empty(nr, nc));
                        let p = GpuMatcher::new(GpuConfig { device_parallelism: 3, ..base })
                            .run_detached(&g, Matching::empty(nr, nc));
                        p.matching
                            .certify(&g)
                            .map_err(|e| format!("{} parallel: {e}", base.name()))?;
                        if s.matching.cardinality() != p.matching.cardinality() {
                            return Err(format!(
                                "{}: serial {} != parallel {}",
                                base.name(),
                                s.matching.cardinality(),
                                p.matching.cardinality()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gpu_run_honours_ctx_interruption_and_reuses_workspaces() {
        let g = crate::graph::gen::Family::Uniform.generate(600, 5);
        let init = InitHeuristic::Cheap.run(&g);
        // pre-cancelled token: trips at the first phase checkpoint, and the
        // returned matching is still the (valid) initial one
        let mut ctx = RunCtx::detached();
        ctx.cancel_token().cancel();
        let r = GpuMatcher::default().run(&g, init.clone(), &mut ctx);
        assert_eq!(r.outcome, RunOutcome::Cancelled);
        r.matching.validate(&g).unwrap();
        assert_eq!(r.matching.cardinality(), init.cardinality());
        // expired deadline behaves the same, tagged differently
        let mut ctx = RunCtx::detached().with_deadline_in(std::time::Duration::ZERO);
        let r = GpuMatcher::default().run(&g, init.clone(), &mut ctx);
        assert_eq!(r.outcome, RunOutcome::DeadlineExceeded);
        // workspace reuse: a second same-size job leases the first job's
        // buffers (bfs_array/predecessor/root + the worklists)
        let pool = std::sync::Arc::new(crate::util::pool::WorkspacePool::new());
        let r1 = GpuMatcher::default().run(&g, init.clone(), &mut RunCtx::new(pool.clone()));
        assert!(r1.is_complete());
        assert_eq!(pool.reuses(), 0);
        let r2 = GpuMatcher::default().run(&g, init, &mut RunCtx::new(pool.clone()));
        assert!(
            pool.reuses() >= 3,
            "second run must lease the first run's device arrays, reuses={}",
            pool.reuses()
        );
        assert_eq!(r1.matching.cardinality(), r2.matching.cardinality());
    }

    #[test]
    fn seeded_repair_restores_maximum_after_edge_deletion() {
        // solve, delete one matched edge, repair seeded only from the
        // exposed column: every variant must land back on the reference
        // cardinality of the mutated graph
        let g = crate::graph::gen::Family::Road.generate(500, 21);
        let solved = GpuMatcher::default()
            .run_detached(&g, InitHeuristic::Cheap.run(&g))
            .matching;
        // drop the first matched edge that is not a bridge-to-nothing
        let c = (0..g.nc).find(|&c| solved.cmatch[c] >= 0).unwrap();
        let r = solved.cmatch[c] as usize;
        let mutated: Vec<(u32, u32)> = g
            .edges()
            .into_iter()
            .filter(|&(er, ec)| !(er as usize == r && ec as usize == c))
            .collect();
        let g2 = from_edges(g.nr, g.nc, &mutated);
        let want = reference_max_cardinality(&g2);
        let mut init = solved;
        init.cmatch[c] = crate::matching::UNMATCHED;
        init.rmatch[r] = crate::matching::UNMATCHED;
        init.validate(&g2).unwrap();
        for cfg in GpuConfig::all_variants_with_frontier() {
            let res = GpuMatcher::new(cfg).run_repair(
                &g2,
                init.clone(),
                &[c as u32],
                &mut RunCtx::detached(),
            );
            res.matching
                .certify(&g2)
                .unwrap_or_else(|e| panic!("{}: {e}", cfg.name()));
            assert_eq!(res.matching.cardinality(), want, "{}", cfg.name());
            assert!(res.is_complete());
        }
    }

    #[test]
    fn seeded_repair_with_empty_seeds_still_certifies_maximum() {
        // an empty seed set must not terminate early: the driver falls
        // through to a full phase and still reaches the maximum
        let g = crate::graph::gen::Family::Uniform.generate(300, 9);
        let want = reference_max_cardinality(&g);
        let init = InitHeuristic::Cheap.run(&g);
        for cfg in [GpuConfig::default(), GpuConfig::default().compacted()] {
            let res =
                GpuMatcher::new(cfg).run_repair(&g, init.clone(), &[], &mut RunCtx::detached());
            res.matching.certify(&g).unwrap();
            assert_eq!(res.matching.cardinality(), want, "{}", cfg.name());
        }
    }

    #[test]
    fn seeded_repair_explores_less_than_full_rerun() {
        // the point of seeding: repairing one lost edge must scan fewer
        // edges in its first phase than re-running from the same matching
        // with every deficiency column active
        let g = crate::graph::gen::Family::Social.generate(2000, 3);
        let solved = GpuMatcher::new(GpuConfig::default().compacted())
            .run_detached(&g, InitHeuristic::Cheap.run(&g))
            .matching;
        let c = (0..g.nc).find(|&c| solved.cmatch[c] >= 0).unwrap();
        let r = solved.cmatch[c] as usize;
        let g2 = from_edges(
            g.nr,
            g.nc,
            &g.edges()
                .into_iter()
                .filter(|&(er, ec)| !(er as usize == r && ec as usize == c))
                .collect::<Vec<_>>(),
        );
        let mut init = solved;
        init.cmatch[c] = crate::matching::UNMATCHED;
        init.rmatch[r] = crate::matching::UNMATCHED;
        let m = GpuMatcher::new(GpuConfig::default().compacted());
        let repaired =
            m.run_repair(&g2, init.clone(), &[c as u32], &mut RunCtx::detached());
        let rerun = m.run(&g2, init, &mut RunCtx::detached());
        assert_eq!(repaired.matching.cardinality(), rerun.matching.cardinality());
        // the rerun's first phase sweeps from *every* deficiency column;
        // the repair's sweeps only from the one seed, and its closing full
        // phase is what the rerun pays anyway — so the modeled bill must
        // come out lower
        assert!(
            repaired.stats.device_cycles < rerun.stats.device_cycles,
            "seeded repair {} must undercut the full warm re-run {}",
            repaired.stats.device_cycles,
            rerun.stats.device_cycles
        );
    }

    #[test]
    fn device_cycles_recorded() {
        let g = crate::graph::gen::Family::Uniform.generate(400, 3);
        let (r, clock) = GpuMatcher::default().run_with_clock(
            &g,
            Matching::empty(g.nr, g.nc),
            &mut RunCtx::detached(),
        );
        assert!(r.stats.device_cycles > 0);
        assert_eq!(r.stats.device_cycles, clock.cycles);
        assert!(clock.launches > 0);
    }

    #[test]
    fn empty_and_edgeless_graphs() {
        let g = from_edges(5, 5, &[]);
        for cfg in GpuConfig::all_variants() {
            let r = GpuMatcher::new(cfg).run_detached(&g, Matching::empty(5, 5));
            assert_eq!(r.matching.cardinality(), 0);
        }
    }
}
