//! The device execution model: how the simulator schedules the paper's
//! CUDA kernels on the host, and the abstract cost model that stands in
//! for GPU wall-clock (DESIGN.md §2).
//!
//! *Scheduling.* A kernel launch over `n` items with `T` total threads is
//! executed as one legal serialization of the GPU's interleaving: items are
//! visited warp-by-warp in the configured [`WriteOrder`]. Intra-warp
//! lockstep (read-all-then-write-all), which is what produces the paper's
//! ALTERNATE inconsistencies, is provided separately by [`WarpStepper`].
//!
//! *Cost model.* Each launch is charged
//! `LAUNCH_OVERHEAD + #active_warps·WARP_COST + Σ_warp max_lane(work)`
//! where a lane's work is `THREAD_SETUP + Σ items (ITEM_COST +
//! edges·EDGE_COST)`. The warp-max term models SIMD divergence; the
//! per-thread setup term is what makes CT (few threads, many items each)
//! cheaper than MT (one item per thread) exactly as the paper observes.
//!
//! *Execution modes.* Five launch executors share that cost model:
//! * [`launch`] — the paper's full-scan sweep over all `n` items;
//! * [`launch_frontier`] — frontier-compacted sweep over an explicit
//!   worklist, charged `FRONTIER_ITEM_COST` per live item plus
//!   `COMPACTION_COST` per next-frontier append (the body reports those),
//!   so late sparse BFS levels stop paying the `O(nc)` scan floor;
//! * [`launch_parallel`] — host-parallel execution of per-item-disjoint
//!   kernels (INITBFSARRAY/FIXMATCHING); modeled cycles are charged
//!   exactly as the serial [`launch`] would, so the figures stay
//!   deterministic while wall-clock drops with host threads;
//! * [`launch_parallel_racy`] / [`launch_frontier_parallel`] — host-
//!   parallel execution of the *racy* kernels (GPUBFS, GPUBFS-WR and
//!   their frontier twins) over [`crate::util::pool::AtomicCells`] views:
//!   claims go through CAS (charged [`CAS_COST`] apiece, reported by the
//!   body), per-item work is recorded into a per-item slot and folded
//!   into the warp cost model after the join, and worklist output is
//!   merged from per-thread buffers in host-thread-id order. Which thread
//!   wins a claim is a legal schedule of the CUDA race, so results are
//!   schedule-independent exactly where the paper's semantics require it
//!   (final cardinality), not bitwise.

use super::config::{ThreadMapping, WriteOrder, WARP_SIZE};
use crate::sanitize::race;
use crate::util::rng::Xoshiro256;

/// Abstract device-cycle accounting (arbitrary units; the harness reports
/// ratios, never absolute values). Two views of the same work:
/// * `cycles` — **serial** warp-sum (a single SM issuing one warp at a
///   time): the right metric for comparing *configurations* (CT vs MT,
///   WR vs plain) because it is deterministic and schedule-free.
/// * `parallel_cycles` — the warp work divided by the device's concurrent
///   warp throughput ([`PARALLEL_WARPS`], a C2050-like 14 SMs × 4
///   effective resident warps), floored by the critical path (the most
///   expensive single warp). This is the stand-in for GPU wall-clock in
///   the cross-hardware figures (DESIGN.md §2).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceClock {
    pub cycles: u64,
    pub parallel_cycles: u64,
    pub launches: u64,
}

pub const LAUNCH_OVERHEAD: u64 = 4_000;
pub const WARP_COST: u64 = 16;
pub const THREAD_SETUP: u64 = 4;
pub const ITEM_COST: u64 = 2;
pub const EDGE_COST: u64 = 1;
/// Per-item charge of a frontier-compacted launch ([`launch_frontier`]):
/// one worklist read + the level-check the full scan also pays. Kept equal
/// to [`ITEM_COST`] so FullScan vs Compacted figures differ only by how
/// *many* items each launch touches, never by a per-item fudge factor.
pub const FRONTIER_ITEM_COST: u64 = 2;
/// Charge per element appended to the next frontier: the atomic queue-tail
/// increment + coalesced store a real compaction kernel pays.
pub const COMPACTION_COST: u64 = 1;
/// Charge per compare-and-swap (or atomic exchange) a racy kernel issues
/// under parallel execution ([`launch_parallel_racy`] and friends): the
/// L2 atomic round-trip a lock-free claim pays on real hardware. The
/// serial executors simulate the same races by write-order arbitration
/// and therefore never pay it — the parallel views are charged honestly
/// rather than pretending atomics are free.
pub const CAS_COST: u64 = 2;
/// concurrent warp slots the parallel model assumes (14 SMs × 4 effective)
pub const PARALLEL_WARPS: u64 = 56;
/// Per-message latency of the modeled inter-device link (sharded
/// execution, `crate::shard`): the fixed cost of moving *any* batch from
/// one device to another — DMA setup + link round-trip, the PCIe/NVLink
/// analogue of [`LAUNCH_OVERHEAD`]. One message is charged per (source
/// shard → destination shard) pair that exchanges a non-empty batch in an
/// exchange step.
pub const EXCHANGE_MSG_COST: u64 = 500;
/// Per-word transfer cost of the modeled interconnect: one 32-bit word
/// moved across the link. Sized relative to [`EDGE_COST`] so the ratio of
/// on-device work to cross-device traffic — not an absolute bandwidth —
/// drives the sharding figures.
pub const EXCHANGE_WORD_COST: u64 = 1;
/// Words per routed frontier item: the `(row, column)` endpoint pair a
/// cross-shard frontier append ships. Partitioner invariant tests tie the
/// boundary-edge count to `exchange_words / EXCHANGE_WORDS_PER_ITEM`.
pub const EXCHANGE_WORDS_PER_ITEM: u64 = 2;

impl DeviceClock {
    pub fn charge_launch(&mut self) {
        self.cycles += LAUNCH_OVERHEAD;
        self.parallel_cycles += LAUNCH_OVERHEAD;
        self.launches += 1;
    }

    /// Charge one kernel launch's warp work to both views.
    pub fn charge_warp_work(&mut self, warp_sum: u64, max_warp: u64) {
        self.cycles += warp_sum;
        self.parallel_cycles += (warp_sum / PARALLEL_WARPS).max(max_warp);
    }

    /// Serial-model "device milliseconds" (1 GHz nominal clock).
    pub fn as_device_ms(&self) -> f64 {
        self.cycles as f64 / 1e6
    }

    /// Parallel-model "device milliseconds" (1 GHz nominal clock).
    pub fn as_parallel_ms(&self) -> f64 {
        self.parallel_cycles as f64 / 1e6
    }
}

/// Per-shard cycle accounting for sharded execution (`crate::shard`): one
/// [`DeviceClock`] per simulated device plus the interconnect tallies.
///
/// The execution model is bulk-synchronous: every shard runs its kernel
/// launches against its own clock, then an exchange step routes
/// cross-shard frontier traffic and a [`ShardClocks::barrier`] advances
/// every shard's *parallel* view to the slowest shard — so after the
/// final barrier the makespan ([`ShardClocks::makespan`]'s
/// `parallel_cycles`) is what one run costs in wall-clock on K devices
/// running concurrently. The *serial* view keeps each shard's own
/// accumulation and reads as **total work across all devices** (sum), so
/// `cycles` stays the work metric it is for one device — a K=1 sharded
/// run bills exactly what the unsharded driver bills.
///
/// Exchange charging follows a per-link bottleneck model: within one
/// exchange step every source shard drives its own link concurrently, so
/// the step's parallel cost is the *max* over source shards of
/// `msgs·EXCHANGE_MSG_COST + words·EXCHANGE_WORD_COST`, while the serial
/// view accumulates the full sum (all traffic through one link).
#[derive(Debug, Clone, Default)]
pub struct ShardClocks {
    clocks: Vec<DeviceClock>,
    /// serial-view exchange bill: the sum over all links of all steps
    exchange_serial_cycles: u64,
    /// total 32-bit words moved across the modeled interconnect
    pub exchange_words: u64,
    /// exchange steps executed (one per BFS level with cross-shard
    /// traffic, plus endpoint gathers / replicated broadcasts)
    pub exchange_steps: u64,
    /// point-to-point messages (non-empty source→dest batches)
    pub exchange_msgs: u64,
}

impl ShardClocks {
    pub fn new(shards: usize) -> Self {
        Self { clocks: vec![DeviceClock::default(); shards.max(1)], ..Self::default() }
    }

    pub fn shards(&self) -> usize {
        self.clocks.len()
    }

    pub fn clock_mut(&mut self, shard: usize) -> &mut DeviceClock {
        &mut self.clocks[shard]
    }

    /// BSP barrier: advance every shard's parallel view to the slowest
    /// shard (idle devices wait; their serial work totals are untouched).
    pub fn barrier(&mut self) {
        let max_par = self.clocks.iter().map(|c| c.parallel_cycles).max().unwrap_or(0);
        for c in &mut self.clocks {
            c.parallel_cycles = max_par;
        }
    }

    /// Charge one exchange step. `per_source` holds, for each source
    /// shard, the `(messages, words)` it pushed onto its link this step.
    /// Parallel view: every clock advances by the bottleneck link's cost
    /// (sources drive their links concurrently; all shards wait out the
    /// slowest link before the next level). Serial view: the full sum,
    /// accumulated separately so [`ShardClocks::makespan`] adds it to the
    /// total exactly once. No-traffic steps charge nothing and don't
    /// count as a step.
    pub fn charge_exchange(&mut self, per_source: &[(u64, u64)]) {
        let mut sum = 0u64;
        let mut bottleneck = 0u64;
        let mut msgs = 0u64;
        let mut words = 0u64;
        for &(m, w) in per_source {
            let link = m * EXCHANGE_MSG_COST + w * EXCHANGE_WORD_COST;
            sum += link;
            bottleneck = bottleneck.max(link);
            msgs += m;
            words += w;
        }
        if sum == 0 {
            return;
        }
        self.exchange_steps += 1;
        self.exchange_msgs += msgs;
        self.exchange_words += words;
        self.exchange_serial_cycles += sum;
        for c in &mut self.clocks {
            c.parallel_cycles += bottleneck;
        }
    }

    /// Charge work every shard performs identically (replicated phases:
    /// INITBFSARRAY, ALTERNATE, FIXMATCHING run mirrored on all devices
    /// over the replicated row arrays): each clock advances by the same
    /// delta — the makespan gains one copy (all devices do it
    /// concurrently), the total-work view gains K copies (each device
    /// really does it).
    pub fn charge_replicated(&mut self, delta: &DeviceClock) {
        for c in &mut self.clocks {
            c.cycles += delta.cycles;
            c.parallel_cycles += delta.parallel_cycles;
            c.launches += delta.launches;
        }
    }

    /// The run's combined bill: `parallel_cycles` is the BSP makespan (max
    /// over shards — call after the final [`ShardClocks::barrier`]),
    /// `cycles` the total work across all devices plus the full serial
    /// exchange bill, `launches` the total kernel launches issued.
    pub fn makespan(&self) -> DeviceClock {
        DeviceClock {
            cycles: self.clocks.iter().map(|c| c.cycles).sum::<u64>()
                + self.exchange_serial_cycles,
            parallel_cycles: self.clocks.iter().map(|c| c.parallel_cycles).max().unwrap_or(0),
            launches: self.clocks.iter().map(|c| c.launches).sum(),
        }
    }
}

/// Iterate the columns assigned to thread `tid` under the paper's strided
/// `getProcessCount` scheme: `col = i·T + tid`.
#[inline]
pub fn thread_items(tid: usize, total_threads: usize, n: usize) -> impl Iterator<Item = usize> {
    (0..)
        .map(move |i| i * total_threads + tid)
        .take_while(move |&c| c < n)
}

/// One kernel launch: visit all `n` items in warp order, calling
/// `body(item) -> edges_scanned`, and charge the cost model. The `order`
/// picks which serialization of the race the simulator realizes.
pub fn launch<F>(
    clock: &mut DeviceClock,
    mapping: ThreadMapping,
    order: WriteOrder,
    seed: u64,
    n: usize,
    mut body: F,
) where
    F: FnMut(usize) -> u64,
{
    clock.charge_launch();
    let total = mapping.total_threads(n);
    // threads with tid >= n own no items under the strided assignment
    // (their first candidate item is already `tid >= n`), so whole warps
    // beyond ceil(min(total, n)/WARP) can be skipped without touching the
    // cost model — inactive warps are never charged anyway. This is the
    // simulator's biggest hot-path win for small graphs under CT
    // (EXPERIMENTS.md §Perf).
    let n_warps = total.min(n.max(1)).div_ceil(WARP_SIZE);
    // §Perf: Forward/Reverse iterate directly — materializing the warp
    // order (one Vec per launch, hundreds of launches per phase) showed up
    // as the #2 allocation site in the level loop.
    let mut shuffled: Vec<usize> = Vec::new();
    let warp_at = |i: usize, shuffled: &[usize]| -> usize {
        match order {
            WriteOrder::Forward => i,
            WriteOrder::Reverse => n_warps - 1 - i,
            WriteOrder::Shuffled => shuffled[i],
        }
    };
    if order == WriteOrder::Shuffled {
        shuffled = (0..n_warps).collect();
        Xoshiro256::new(seed ^ clock.launches).shuffle(&mut shuffled);
    }
    let mut warp_sum = 0u64;
    let mut max_warp = 0u64;
    for i in 0..n_warps {
        let w = warp_at(i, &shuffled);
        let mut warp_max: u64 = 0;
        let mut warp_active = false;
        for lane in 0..WARP_SIZE {
            let tid = w * WARP_SIZE + lane;
            if tid >= total {
                break;
            }
            let mut lane_work: u64 = 0;
            let mut any = false;
            for item in thread_items(tid, total, n) {
                any = true;
                let edges = body(item);
                lane_work += ITEM_COST + edges * EDGE_COST;
            }
            if any {
                lane_work += THREAD_SETUP;
                warp_active = true;
            }
            warp_max = warp_max.max(lane_work);
        }
        if warp_active {
            let cost = WARP_COST + warp_max;
            warp_sum += cost;
            max_warp = max_warp.max(cost);
        }
    }
    clock.charge_warp_work(warp_sum, max_warp);
}

/// One frontier-compacted kernel launch: visit exactly the columns in
/// `items` (the current BFS frontier) in warp order, calling
/// `body(column) -> extra_work_units`, and charge the cost model
/// `FRONTIER_ITEM_COST` per item plus whatever the body reports (edge
/// scans weighted by [`EDGE_COST`], next-frontier appends weighted by
/// [`COMPACTION_COST`] — the body does the weighting so this executor
/// stays kernel-agnostic). Per-launch cost is `O(|items| + work(items))`
/// instead of [`launch`]'s `O(nc)` floor — the whole point of
/// [`super::config::FrontierMode::Compacted`].
pub fn launch_frontier<F>(
    clock: &mut DeviceClock,
    mapping: ThreadMapping,
    order: WriteOrder,
    seed: u64,
    items: &[u32],
    mut body: F,
) where
    F: FnMut(usize) -> u64,
{
    clock.charge_launch();
    let n = items.len();
    let total = mapping.total_threads(n);
    let n_warps = total.min(n.max(1)).div_ceil(WARP_SIZE);
    let mut shuffled: Vec<usize> = Vec::new();
    if order == WriteOrder::Shuffled {
        shuffled = (0..n_warps).collect();
        Xoshiro256::new(seed ^ clock.launches).shuffle(&mut shuffled);
    }
    let warp_at = |i: usize, shuffled: &[usize]| -> usize {
        match order {
            WriteOrder::Forward => i,
            WriteOrder::Reverse => n_warps - 1 - i,
            WriteOrder::Shuffled => shuffled[i],
        }
    };
    let mut warp_sum = 0u64;
    let mut max_warp = 0u64;
    for i in 0..n_warps {
        let w = warp_at(i, &shuffled);
        let mut warp_max: u64 = 0;
        let mut warp_active = false;
        for lane in 0..WARP_SIZE {
            let tid = w * WARP_SIZE + lane;
            if tid >= total {
                break;
            }
            let mut lane_work: u64 = 0;
            let mut any = false;
            for idx in thread_items(tid, total, n) {
                any = true;
                let work = body(items[idx] as usize);
                lane_work += FRONTIER_ITEM_COST + work;
            }
            if any {
                lane_work += THREAD_SETUP;
                warp_active = true;
            }
            warp_max = warp_max.max(lane_work);
        }
        if warp_active {
            let cost = WARP_COST + warp_max;
            warp_sum += cost;
            max_warp = max_warp.max(cost);
        }
    }
    clock.charge_warp_work(warp_sum, max_warp);
}

/// Fold per-item work into the launch cost model: lane work is
/// `Σ items (per_item + work(item)) + THREAD_SETUP`, warps charge
/// `WARP_COST + max_lane`. This reproduces exactly what [`launch`]
/// (`per_item = ITEM_COST`, `work = edges·EDGE_COST`) or
/// [`launch_frontier`] (`per_item = FRONTIER_ITEM_COST`) would have
/// charged for the same per-item work, independent of execution order —
/// which is what lets the parallel racy executors run bodies on host
/// threads and settle the bill deterministically afterwards, and what
/// the uniform-scan charges below reuse with zero work.
fn fold_lane_cost<W>(total: usize, n: usize, per_item: u64, work: W) -> (u64, u64)
where
    W: Fn(usize) -> u64,
{
    let n_warps = total.min(n.max(1)).div_ceil(WARP_SIZE);
    let mut warp_sum = 0u64;
    let mut max_warp = 0u64;
    for w in 0..n_warps {
        let mut warp_max: u64 = 0;
        let mut warp_active = false;
        for lane in 0..WARP_SIZE {
            let tid = w * WARP_SIZE + lane;
            if tid >= total {
                break;
            }
            let mut lane_work: u64 = 0;
            let mut any = false;
            for item in thread_items(tid, total, n) {
                any = true;
                lane_work += per_item + work(item);
            }
            if any {
                lane_work += THREAD_SETUP;
                warp_active = true;
            }
            warp_max = warp_max.max(lane_work);
        }
        if warp_active {
            let cost = WARP_COST + warp_max;
            warp_sum += cost;
            max_warp = max_warp.max(cost);
        }
    }
    (warp_sum, max_warp)
}

/// Exact cost [`launch`] charges for a zero-edge body over `n` items —
/// order-independent, so [`launch_parallel`] can charge it without
/// serializing.
fn warp_cost_uniform(total: usize, n: usize) -> (u64, u64) {
    fold_lane_cost(total, n, ITEM_COST, |_| 0)
}

/// Charge the cost of a zero-edge device sweep over `n` items *without*
/// a separate launch: used for selection scans that ride inside another
/// kernel's launch (e.g. ALTERNATE scanning all rows for `-2` endpoints
/// under `FrontierMode::FullScan` — the scan the compacted endpoint
/// worklist eliminates).
pub fn charge_uniform_scan(clock: &mut DeviceClock, mapping: ThreadMapping, n: usize) {
    let (warp_sum, max_warp) = warp_cost_uniform(mapping.total_threads(n), n);
    clock.charge_warp_work(warp_sum, max_warp);
}

/// The worklist counterpart of [`charge_uniform_scan`]: a zero-work
/// frontier-shaped sweep over `n_items` entries (charged
/// [`FRONTIER_ITEM_COST`] apiece under the full warp model), e.g. the
/// compacted ALTERNATE's chosen-endpoint filter reading the endpoint
/// worklist.
pub fn charge_frontier_scan(clock: &mut DeviceClock, mapping: ThreadMapping, n_items: usize) {
    let (warp_sum, max_warp) =
        fold_lane_cost(mapping.total_threads(n_items), n_items, FRONTIER_ITEM_COST, |_| 0);
    clock.charge_warp_work(warp_sum, max_warp);
}

/// Parallel host execution of a *racy* kernel (GPUBFS, GPUBFS-WR): the
/// body runs over all `n` items on `nthreads` host threads (contiguous
/// chunks), mutating shared state through
/// [`crate::util::pool::AtomicCells`] CAS/swap claims, and returns its
/// weighted work units (`EDGE_COST` per edge, [`CAS_COST`] per atomic it
/// issued, ...). Work is recorded per item and folded into the serial
/// warp cost model after the join, so modeled cycles are a deterministic
/// function of the per-item work even though host scheduling is not.
/// `body(host_tid, item)` receives the host-thread id so kernels can keep
/// per-thread output buffers and merge them deterministically by id.
/// No [`WriteOrder`] applies: claim arbitration is the hardware race
/// itself, and any interleaving is one of the legal schedules the serial
/// orders enumerate.
///
/// `work` is the per-item work-unit record, caller-owned so its capacity
/// survives across the hundreds of launches one run issues (the driver
/// leases it from the [`crate::util::pool::WorkspacePool`] via `GpuState`
/// instead of paying a `vec![0u64; n]` allocation per launch); it is
/// cleared and refilled here, contents on entry don't matter.
///
/// `kernel` names the launch in race-sanitizer diagnostics
/// (`crate::sanitize::race`): when `BIMATCH_SANITIZE=1`, every shared
/// access the body makes is shadow-logged per modeled item, and the
/// launch end flags non-atomic same-cell conflicts plus atomic RMWs the
/// per-item work record did not charge [`CAS_COST`] for.
pub fn launch_parallel_racy<F>(
    clock: &mut DeviceClock,
    mapping: ThreadMapping,
    kernel: &'static str,
    n: usize,
    nthreads: usize,
    work: &mut Vec<u64>,
    body: F,
) where
    F: Fn(usize, usize) -> u64 + Sync,
{
    clock.charge_launch();
    let nthreads = nthreads.max(1);
    work.clear();
    work.resize(n, 0);
    let shadow = race::launch_scope(kernel);
    {
        let w = crate::util::pool::SharedSlice::new(work);
        let per = n.div_ceil(nthreads).max(1);
        crate::util::pool::fork_join(nthreads, |tid| {
            let _lane = shadow.as_ref().map(|s| s.enter(tid as u32));
            let lo = (tid * per).min(n);
            let hi = ((tid + 1) * per).min(n);
            for item in lo..hi {
                race::set_item(item as u32);
                let units = body(tid, item);
                // SAFETY: index `item` belongs to this thread's chunk only.
                unsafe { w.set(item, units) };
            }
        });
    }
    if let Some(s) = shadow {
        s.finish(race::CostCheck::PerItem { work: work.as_slice(), per_rmw: CAS_COST }, None);
    }
    let (warp_sum, max_warp) =
        fold_lane_cost(mapping.total_threads(n), n, ITEM_COST, |item| work[item]);
    clock.charge_warp_work(warp_sum, max_warp);
}

/// [`launch_parallel_racy`] over an explicit frontier worklist: visits
/// exactly `items`, charges [`FRONTIER_ITEM_COST`] per item plus the work
/// the body reports (which should include [`COMPACTION_COST`] per
/// worklist append and [`CAS_COST`] per atomic, like the serial
/// [`launch_frontier`] bodies do). `work` is the caller-owned per-item
/// record, as in [`launch_parallel_racy`], and `kernel` names the launch
/// in sanitizer diagnostics. Shadow logging is keyed by frontier
/// *position* (matching the `work` record); diagnostics translate
/// positions back to column ids through `items`.
pub fn launch_frontier_parallel<F>(
    clock: &mut DeviceClock,
    mapping: ThreadMapping,
    kernel: &'static str,
    items: &[u32],
    nthreads: usize,
    work: &mut Vec<u64>,
    body: F,
) where
    F: Fn(usize, usize) -> u64 + Sync,
{
    clock.charge_launch();
    let n = items.len();
    let nthreads = nthreads.max(1);
    work.clear();
    work.resize(n, 0);
    let shadow = race::launch_scope(kernel);
    {
        let w = crate::util::pool::SharedSlice::new(work);
        let per = n.div_ceil(nthreads).max(1);
        crate::util::pool::fork_join(nthreads, |tid| {
            let _lane = shadow.as_ref().map(|s| s.enter(tid as u32));
            let lo = (tid * per).min(n);
            let hi = ((tid + 1) * per).min(n);
            for idx in lo..hi {
                race::set_item(idx as u32);
                let units = body(tid, items[idx] as usize);
                // SAFETY: index `idx` belongs to this thread's chunk only.
                unsafe { w.set(idx, units) };
            }
        });
    }
    if let Some(s) = shadow {
        s.finish(
            race::CostCheck::PerItem { work: work.as_slice(), per_rmw: CAS_COST },
            Some(items),
        );
    }
    let (warp_sum, max_warp) =
        fold_lane_cost(mapping.total_threads(n), n, FRONTIER_ITEM_COST, |idx| work[idx]);
    clock.charge_warp_work(warp_sum, max_warp);
}

/// Parallel host execution of a *per-item-disjoint* kernel (INITBFSARRAY,
/// FIXMATCHING): `body(item)` runs on `nthreads` host threads via the
/// scoped pool, while the device clock is charged exactly what the serial
/// [`launch`] would charge for a zero-edge body — modeled cycles stay
/// deterministic and independent of host parallelism; only wall-clock
/// changes. The caller guarantees `body` writes disjoint indices (use
/// [`crate::util::pool::SharedSlice`]); write order is immaterial for such
/// kernels, which is why no [`WriteOrder`] parameter exists here.
///
/// `kernel` names the launch in race-sanitizer diagnostics. Because the
/// disjointness promise is exactly what this executor's cost formula
/// assumes (no CAS charged, ever), the sanitizer holds its launches to
/// the strictest contract: *any* cross-item conflict and *any* atomic
/// RMW is an error.
pub fn launch_parallel<F>(
    clock: &mut DeviceClock,
    mapping: ThreadMapping,
    kernel: &'static str,
    n: usize,
    nthreads: usize,
    body: F,
) where
    F: Fn(usize) + Sync,
{
    clock.charge_launch();
    let (warp_sum, max_warp) = warp_cost_uniform(mapping.total_threads(n), n);
    clock.charge_warp_work(warp_sum, max_warp);
    let shadow = race::launch_scope(kernel);
    let nthreads = nthreads.max(1);
    let per = n.div_ceil(nthreads).max(1);
    crate::util::pool::fork_join(nthreads, |tid| {
        let _lane = shadow.as_ref().map(|s| s.enter(tid as u32));
        let lo = (tid * per).min(n);
        let hi = ((tid + 1) * per).min(n);
        for i in lo..hi {
            race::set_item(i as u32);
            body(i);
        }
    });
    if let Some(s) = shadow {
        s.finish(race::CostCheck::Disjoint, None);
    }
}

/// Lockstep executor for ALTERNATE: all lanes of a warp perform a *read*
/// step against the same memory snapshot logic, then their writes are
/// applied in lane order — reproducing the paper's intra-warp
/// inconsistency ("the if-check will not hold for both threads, and their
/// row vertices will be written on cmatch; only one will be successful").
///
/// Threads are the active items (e.g. endpoint rows); each is stepped
/// until every thread reports completion.
pub struct WarpStepper {
    pub order: WriteOrder,
    pub seed: u64,
}

/// Outcome of one lockstep read-step of a single lane.
pub enum StepPlan<W> {
    /// thread finished
    Done,
    /// thread wants to apply `write` then continue
    Write(W),
}

impl WarpStepper {
    /// Drive `threads` (item payloads) in warps of `WARP_SIZE` against a
    /// shared memory `mem`. `read_step(mem, thread)` plans a write from
    /// the current memory; `apply(mem, thread, plan)` commits it and
    /// returns whether the thread continues. Cost: each lockstep round
    /// charges like one item per lane.
    pub fn run<T, M, R, A, W>(
        &self,
        clock: &mut DeviceClock,
        threads: &mut [T],
        mem: &mut M,
        mut read_step: R,
        mut apply: A,
    ) where
        R: FnMut(&M, &T) -> StepPlan<W>,
        A: FnMut(&mut M, &mut T, W) -> bool,
    {
        clock.charge_launch();
        let n = threads.len();
        if n == 0 {
            return;
        }
        let n_warps = n.div_ceil(WARP_SIZE);
        let warp_order: Vec<usize> = match self.order {
            WriteOrder::Forward => (0..n_warps).collect(),
            WriteOrder::Reverse => (0..n_warps).rev().collect(),
            WriteOrder::Shuffled => {
                let mut v: Vec<usize> = (0..n_warps).collect();
                Xoshiro256::new(self.seed).shuffle(&mut v);
                v
            }
        };
        let mut alive: Vec<bool> = vec![true; n];
        // warps run until all their lanes retire; warps are scheduled
        // round-robin in warp_order (one lockstep round each) so long
        // chains in different warps interleave, like resident warps on an
        // SM.
        let mut any_alive = true;
        while any_alive {
            any_alive = false;
            // one global round: every warp performs one lockstep step; the
            // parallel model charges the max warp cost of the round
            let mut round_sum = 0u64;
            let mut round_max = 0u64;
            for &w in &warp_order {
                let lo = w * WARP_SIZE;
                let hi = ((w + 1) * WARP_SIZE).min(n);
                // read phase: plan all lanes against the same memory state
                let mut plans: Vec<(usize, W)> = Vec::with_capacity(hi - lo);
                let mut round_work = 0u64;
                for i in lo..hi {
                    if !alive[i] {
                        continue;
                    }
                    round_work += ITEM_COST;
                    match read_step(mem, &threads[i]) {
                        StepPlan::Done => alive[i] = false,
                        StepPlan::Write(wr) => plans.push((i, wr)),
                    }
                }
                // write phase: commit in lane order
                for (i, wr) in plans {
                    if !apply(mem, &mut threads[i], wr) {
                        alive[i] = false;
                    }
                }
                if round_work > 0 {
                    let cost = WARP_COST + round_work;
                    round_sum += cost;
                    round_max = round_max.max(cost);
                }
                any_alive |= alive[lo..hi].iter().any(|&a| a);
            }
            clock.charge_warp_work(round_sum, round_max);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::config::ThreadMapping;

    #[test]
    fn thread_items_strided() {
        let items: Vec<usize> = thread_items(1, 4, 10).collect();
        assert_eq!(items, vec![1, 5, 9]);
        let none: Vec<usize> = thread_items(7, 8, 5).collect();
        assert!(none.is_empty());
    }

    #[test]
    fn launch_visits_every_item_once() {
        for mapping in [ThreadMapping::Ct, ThreadMapping::Mt] {
            for order in [WriteOrder::Forward, WriteOrder::Reverse, WriteOrder::Shuffled] {
                let n = 1000;
                let mut clock = DeviceClock::default();
                let mut seen = vec![0u32; n];
                launch(&mut clock, mapping, order, 42, n, |i| {
                    seen[i] += 1;
                    1
                });
                assert!(seen.iter().all(|&s| s == 1), "{mapping:?} {order:?}");
                assert_eq!(clock.launches, 1);
                assert!(clock.cycles > LAUNCH_OVERHEAD);
            }
        }
    }

    #[test]
    fn ct_cheaper_than_mt_on_large_n() {
        // the paper's CT-beats-MT observation must hold in the cost model
        let n = 300_000;
        let mut ct = DeviceClock::default();
        launch(&mut ct, ThreadMapping::Ct, WriteOrder::Forward, 0, n, |_| 2);
        let mut mt = DeviceClock::default();
        launch(&mut mt, ThreadMapping::Mt, WriteOrder::Forward, 0, n, |_| 2);
        assert!(
            ct.cycles < mt.cycles,
            "CT {} should be < MT {}",
            ct.cycles,
            mt.cycles
        );
    }

    #[test]
    fn reverse_order_flips_visit_sequence() {
        let n = 64;
        let mut fwd_order = Vec::new();
        let mut clock = DeviceClock::default();
        launch(&mut clock, ThreadMapping::Mt, WriteOrder::Forward, 0, n, |i| {
            fwd_order.push(i);
            0
        });
        let mut rev_order = Vec::new();
        launch(&mut clock, ThreadMapping::Mt, WriteOrder::Reverse, 0, n, |i| {
            rev_order.push(i);
            0
        });
        assert_ne!(fwd_order, rev_order);
        let mut r = rev_order.clone();
        r.sort_unstable();
        assert_eq!(r, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn launch_frontier_visits_exactly_the_items() {
        for mapping in [ThreadMapping::Ct, ThreadMapping::Mt] {
            for order in [WriteOrder::Forward, WriteOrder::Reverse, WriteOrder::Shuffled] {
                let items: Vec<u32> = vec![5, 1, 9, 42, 7];
                let mut clock = DeviceClock::default();
                let mut seen = vec![0u32; 64];
                launch_frontier(&mut clock, mapping, order, 3, &items, |c| {
                    seen[c] += 1;
                    1
                });
                for (c, &count) in seen.iter().enumerate() {
                    let expect = u32::from(items.contains(&(c as u32)));
                    assert_eq!(count, expect, "{mapping:?} {order:?} col {c}");
                }
                assert_eq!(clock.launches, 1);
            }
        }
    }

    #[test]
    fn launch_frontier_empty_is_cheap_and_safe() {
        let mut clock = DeviceClock::default();
        launch_frontier(&mut clock, ThreadMapping::Ct, WriteOrder::Forward, 0, &[], |_| {
            panic!("empty frontier must not invoke the body")
        });
        assert_eq!(clock.cycles, LAUNCH_OVERHEAD);
    }

    #[test]
    fn sparse_frontier_launch_beats_full_scan() {
        // 100k columns, 64 live: the full scan pays ITEM_COST for every
        // column; the compacted launch only touches the worklist.
        let n = 100_000;
        let live: Vec<u32> = (0..64u32).map(|i| i * 1000).collect();
        let is_live = |c: usize| c % 1000 == 0 && c < 64_000;
        let mut full = DeviceClock::default();
        launch(&mut full, ThreadMapping::Ct, WriteOrder::Forward, 0, n, |c| {
            if is_live(c) {
                3
            } else {
                0
            }
        });
        let mut fc = DeviceClock::default();
        launch_frontier(&mut fc, ThreadMapping::Ct, WriteOrder::Forward, 0, &live, |c| {
            assert!(is_live(c));
            3 * EDGE_COST + COMPACTION_COST
        });
        assert!(
            fc.cycles * 10 < full.cycles,
            "compacted {} should be well under full {}",
            fc.cycles,
            full.cycles
        );
    }

    #[test]
    fn launch_parallel_matches_serial_cost_and_effect() {
        use std::sync::atomic::{AtomicU32, Ordering};
        for mapping in [ThreadMapping::Ct, ThreadMapping::Mt] {
            for n in [0usize, 1, 33, 1000, 70_000] {
                let mut serial = DeviceClock::default();
                let mut seen = vec![0u32; n];
                launch(&mut serial, mapping, WriteOrder::Forward, 0, n, |i| {
                    seen[i] += 1;
                    0
                });
                for nthreads in [1usize, 4] {
                    let mut par = DeviceClock::default();
                    let pseen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
                    launch_parallel(&mut par, mapping, "TEST-DISJOINT", n, nthreads, |i| {
                        pseen[i].fetch_add(1, Ordering::Relaxed);
                    });
                    assert_eq!(
                        par.cycles, serial.cycles,
                        "{mapping:?} n={n} t={nthreads}: modeled cycles must not depend on host threads"
                    );
                    assert_eq!(par.parallel_cycles, serial.parallel_cycles);
                    assert!(pseen.iter().all(|a| a.load(Ordering::Relaxed) == 1) || n == 0);
                }
            }
        }
    }

    #[test]
    fn launch_parallel_racy_matches_serial_cost_for_cas_free_body() {
        // a body that issues no atomics must cost exactly what the serial
        // launch charges for the same per-item edge counts
        use std::sync::atomic::{AtomicU32, Ordering};
        // one scratch buffer reused across every launch: reuse must not
        // change the bill or the coverage
        let mut scratch = Vec::new();
        for mapping in [ThreadMapping::Ct, ThreadMapping::Mt] {
            for n in [0usize, 1, 33, 1000, 70_000] {
                let mut serial = DeviceClock::default();
                launch(&mut serial, mapping, WriteOrder::Forward, 0, n, |i| (i % 3) as u64);
                for nthreads in [1usize, 4] {
                    let mut par = DeviceClock::default();
                    let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
                    launch_parallel_racy(
                        &mut par,
                        mapping,
                        "TEST-RACY",
                        n,
                        nthreads,
                        &mut scratch,
                        |_tid, i| {
                            seen[i].fetch_add(1, Ordering::Relaxed);
                            (i % 3) as u64 * EDGE_COST
                        },
                    );
                    assert_eq!(par.cycles, serial.cycles, "{mapping:?} n={n} t={nthreads}");
                    assert_eq!(par.parallel_cycles, serial.parallel_cycles);
                    assert!(seen.iter().all(|a| a.load(Ordering::Relaxed) == 1));
                }
            }
        }
    }

    #[test]
    fn launch_frontier_parallel_matches_serial_frontier_cost() {
        let items: Vec<u32> = (0..777u32).map(|i| i * 3).collect();
        let mut scratch = Vec::new();
        for mapping in [ThreadMapping::Ct, ThreadMapping::Mt] {
            let mut serial = DeviceClock::default();
            launch_frontier(&mut serial, mapping, WriteOrder::Forward, 0, &items, |c| {
                (c % 5) as u64
            });
            let mut par = DeviceClock::default();
            launch_frontier_parallel(
                &mut par,
                mapping,
                "TEST-FRONTIER",
                &items,
                4,
                &mut scratch,
                |_tid, c| (c % 5) as u64,
            );
            assert_eq!(par.cycles, serial.cycles, "{mapping:?}");
            assert_eq!(par.parallel_cycles, serial.parallel_cycles);
        }
    }

    #[test]
    fn charge_uniform_scan_costs_like_zero_edge_launch_body() {
        let n = 5000;
        let mut scan = DeviceClock::default();
        scan.charge_launch();
        charge_uniform_scan(&mut scan, ThreadMapping::Ct, n);
        let mut full = DeviceClock::default();
        launch(&mut full, ThreadMapping::Ct, WriteOrder::Forward, 0, n, |_| 0);
        assert_eq!(scan.cycles, full.cycles);
        assert_eq!(scan.parallel_cycles, full.parallel_cycles);
    }

    #[test]
    fn charge_frontier_scan_costs_like_zero_work_frontier_launch() {
        let items: Vec<u32> = (0..777u32).collect();
        let mut scan = DeviceClock::default();
        scan.charge_launch();
        charge_frontier_scan(&mut scan, ThreadMapping::Ct, items.len());
        let mut launched = DeviceClock::default();
        launch_frontier(&mut launched, ThreadMapping::Ct, WriteOrder::Forward, 0, &items, |_| 0);
        assert_eq!(scan.cycles, launched.cycles);
        assert_eq!(scan.parallel_cycles, launched.parallel_cycles);
    }

    #[test]
    fn shard_clocks_barrier_advances_parallel_to_slowest() {
        let mut sc = ShardClocks::new(3);
        sc.clock_mut(0).cycles = 100;
        sc.clock_mut(0).parallel_cycles = 10;
        sc.clock_mut(2).cycles = 250;
        sc.clock_mut(2).parallel_cycles = 40;
        sc.barrier();
        for s in 0..3 {
            assert_eq!(sc.clock_mut(s).parallel_cycles, 40, "idle shards wait out the slowest");
        }
        // serial view is total work: barriers never inflate it
        assert_eq!(sc.clock_mut(0).cycles, 100);
        assert_eq!(sc.makespan().cycles, 350);
        assert_eq!(sc.makespan().parallel_cycles, 40);
    }

    #[test]
    fn charge_exchange_bottleneck_vs_sum() {
        let mut sc = ShardClocks::new(2);
        // shard 0 ships 1 msg / 10 words, shard 1 ships 2 msgs / 4 words
        sc.charge_exchange(&[(1, 10), (2, 4)]);
        let link0 = EXCHANGE_MSG_COST + 10 * EXCHANGE_WORD_COST;
        let link1 = 2 * EXCHANGE_MSG_COST + 4 * EXCHANGE_WORD_COST;
        let m = sc.makespan();
        // parallel view: the slower link bounds the step
        assert_eq!(m.parallel_cycles, link0.max(link1));
        // serial view: all traffic through one link
        assert_eq!(m.cycles, link0 + link1);
        assert_eq!(sc.exchange_steps, 1);
        assert_eq!(sc.exchange_msgs, 3);
        assert_eq!(sc.exchange_words, 14);
        // a traffic-free exchange is free and uncounted
        sc.charge_exchange(&[(0, 0), (0, 0)]);
        assert_eq!(sc.exchange_steps, 1);
        assert_eq!(sc.makespan(), m);
    }

    #[test]
    fn charge_replicated_bills_one_makespan_copy_and_k_work_copies() {
        let mut sc = ShardClocks::new(4);
        let delta = DeviceClock { cycles: 7, parallel_cycles: 3, launches: 1 };
        sc.charge_replicated(&delta);
        sc.charge_replicated(&delta);
        for s in 0..4 {
            assert_eq!(
                *sc.clock_mut(s),
                DeviceClock { cycles: 14, parallel_cycles: 6, launches: 2 }
            );
        }
        let m = sc.makespan();
        // makespan: one copy (all devices mirror it concurrently);
        // total work: K copies (each device really does it)
        assert_eq!(m.parallel_cycles, 6);
        assert_eq!(m.cycles, 4 * 14);
        assert_eq!(m.launches, 8);
    }

    #[test]
    fn single_shard_clocks_degenerate_to_one_device() {
        let mut sc = ShardClocks::new(1);
        let mut plain = DeviceClock::default();
        launch(&mut plain, ThreadMapping::Ct, WriteOrder::Forward, 0, 500, |_| 1);
        launch(sc.clock_mut(0), ThreadMapping::Ct, WriteOrder::Forward, 0, 500, |_| 1);
        sc.barrier();
        assert_eq!(sc.makespan().cycles, plain.cycles);
        assert_eq!(sc.makespan().parallel_cycles, plain.parallel_cycles);
        assert_eq!(sc.exchange_words, 0);
    }

    #[test]
    fn warp_stepper_lockstep_races() {
        // 33 threads all try to claim slot 0 (CAS-less write): in lockstep,
        // every lane of warp 0 reads "free" and plans a write; lane order
        // decides; threads in warp 1 see the committed value and stop.
        let mut slot = -1i64;
        let mut threads: Vec<i64> = (0..33).collect();
        let mut claims = 0usize;
        let stepper = WarpStepper { order: WriteOrder::Forward, seed: 0 };
        let mut clock = DeviceClock::default();
        // plan: if slot free, write my id; else done.
        // apply: last writer wins; count every commit.
        stepper.run(
            &mut clock,
            &mut threads,
            &mut slot,
            |slot, &t| {
                if *slot == -1 {
                    StepPlan::Write(t)
                } else {
                    StepPlan::Done
                }
            },
            |slot, _t, w| {
                *slot = w;
                claims += 1;
                false
            },
        );
        // all 32 lanes of warp 0 raced and wrote (the paper's
        // inconsistency); thread 32 in warp 1 observed the winner and quit.
        assert_eq!(claims, 32);
        assert_eq!(slot, 31); // last lane's write wins under Forward order
    }
}
