//! XLA/PJRT execution of the paper's algorithm: the L1/L2 artifacts
//! (Pallas-in-JAX level kernel, full APFB program) loaded through
//! [`crate::runtime::Engine`] and driven from Rust.
//!
//! Two matchers:
//! * [`XlaApfbMatcher`] — the whole matching loop runs as one compiled
//!   XLA program (`apfb_full_*.hlo.txt`); Rust only packs the graph,
//!   feeds buffers, and certifies the result.
//! * [`XlaHybridMatcher`] — Rust drives the phase loop, calling the
//!   `bfs_level_*.hlo.txt` kernel once per BFS level and running
//!   ALTERNATE/FIXMATCHING on the host device simulator; demonstrates
//!   L3↔L1 composition at kernel granularity.
//!
//! Graphs are ELL-packed without column splitting (the padded columns are
//! isolated vertices, harmless for matching); a graph fits a bucket iff
//! `nc ≤ bucket.nc && nr ≤ bucket.nr && max_col_degree ≤ bucket.k`.

use super::config::{ThreadMapping, WriteOrder};
use super::device::DeviceClock;
use super::kernels::{alternate, fixmatching, GpuState, LaunchCfg, L0};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunResult, RunStats};
use crate::matching::Matching;
use crate::runtime::{Artifact, ArtifactKind, Engine};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Dense ELL (no splitting) padded to an artifact bucket.
fn pack_for_bucket(g: &BipartiteCsr, a: &Artifact) -> Result<Vec<i32>> {
    if g.nc > a.nc || g.nr > a.nr {
        return Err(anyhow!(
            "graph {}x{} does not fit bucket {}x{}",
            g.nr, g.nc, a.nr, a.nc
        ));
    }
    let maxdeg = g.max_col_degree();
    if maxdeg > a.k {
        return Err(anyhow!("max column degree {maxdeg} exceeds bucket K={}", a.k));
    }
    let mut adj = vec![-1i32; a.nc * a.k];
    for c in 0..g.nc {
        for (j, &r) in g.col_neighbors(c).iter().enumerate() {
            adj[c * a.k + j] = r as i32;
        }
    }
    Ok(adj)
}

/// Pick the smallest bucket of `kind` that fits `g`.
fn pick_bucket<'e>(engine: &'e Engine, kind: ArtifactKind, g: &BipartiteCsr) -> Result<&'e Artifact> {
    let maxdeg = g.max_col_degree();
    engine
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == kind && a.nc >= g.nc && a.nr >= g.nr && a.k >= maxdeg)
        .min_by_key(|a| (a.nc as u64) * (a.k as u64) + a.nr as u64)
        .ok_or_else(|| {
            anyhow!(
                "no {kind:?} artifact fits nc={} nr={} maxdeg={maxdeg}; \
                 rebuild with `make artifacts BUCKETS=...`",
                g.nc, g.nr
            )
        })
}

fn pad_i32(v: &[i32], len: usize, fill: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    out.extend_from_slice(v);
    out.resize(len, fill);
    out
}

/// Full-program matcher: one PJRT execution computes the maximum matching.
pub struct XlaApfbMatcher {
    pub engine: Arc<Engine>,
}

impl XlaApfbMatcher {
    pub fn new(engine: Arc<Engine>) -> Self {
        Self { engine }
    }

    pub fn try_run(&self, g: &BipartiteCsr, init: &Matching) -> Result<RunResult> {
        let art = pick_bucket(&self.engine, ArtifactKind::ApfbFull, g)?;
        let adj = pack_for_bucket(g, art)?;
        let rmatch = pad_i32(&init.rmatch, art.nr, -1);
        let cmatch = pad_i32(&init.cmatch, art.nc, -1);
        let exe = self.engine.load(&art.name)?;
        let outs = exe.run_i32(&[
            (&adj, &[art.nc as i64, art.k as i64]),
            (&rmatch, &[art.nr as i64]),
            (&cmatch, &[art.nc as i64]),
        ])?;
        let [rm, cm, phases, launches]: &[Vec<i32>; 4] = outs
            .as_slice()
            .try_into()
            .map_err(|_| anyhow!("expected 4 outputs, got {}", outs.len()))?;
        let matching = Matching {
            rmatch: rm[..g.nr].to_vec(),
            cmatch: cm[..g.nc].to_vec(),
        };
        let mut stats = RunStats::default();
        stats.phases = phases.first().copied().unwrap_or(0).max(0) as u64;
        stats.bfs_kernel_launches = launches.first().copied().unwrap_or(0).max(0) as u64;
        Ok(RunResult::with_stats(matching, stats))
    }
}

impl MatchingAlgorithm for XlaApfbMatcher {
    fn name(&self) -> String {
        "xla:apfb-full".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        // the whole matching runs as ONE compiled program, so the only
        // inter-phase checkpoint is before launch
        if let Some(trip) = ctx.checkpoint() {
            return ctx.finish_with(init, trip);
        }
        match self.try_run(g, &init) {
            Ok(r) => r,
            Err(e) => {
                // no fitting artifact (or PJRT failure): fall back to the
                // native simulator so the service keeps answering; the
                // fallback is visible in the stats.
                log::warn!("xla backend unavailable ({e:#}); using native GPU simulator");
                let mut r = super::driver::GpuMatcher::default().run(g, init, &mut ctx.fork());
                r.stats.fallbacks += 1;
                r
            }
        }
    }
}

/// Hybrid matcher: device (XLA) BFS levels, host ALTERNATE + FIXMATCHING.
pub struct XlaHybridMatcher {
    pub engine: Arc<Engine>,
}

impl XlaHybridMatcher {
    pub fn new(engine: Arc<Engine>) -> Self {
        Self { engine }
    }

    pub fn try_run(&self, g: &BipartiteCsr, init: &Matching) -> Result<RunResult> {
        self.try_run_ctx(g, init, &mut RunCtx::detached())
    }

    /// Context-aware variant: the deadline/cancellation checkpoint sits at
    /// the top of each phase (one `bfs_level` program execution sequence).
    pub fn try_run_ctx(
        &self,
        g: &BipartiteCsr,
        init: &Matching,
        ctx: &mut RunCtx,
    ) -> Result<RunResult> {
        let art = pick_bucket(&self.engine, ArtifactKind::BfsLevel, g)?;
        let adj = pack_for_bucket(g, art)?;
        let exe = self.engine.load(&art.name)?;
        let cfg = LaunchCfg {
            mapping: ThreadMapping::Ct,
            order: WriteOrder::Forward,
            ..LaunchCfg::default()
        };
        let mut clock = DeviceClock::default();
        let mut stats = RunStats::default();
        let mut state = GpuState::new(g, init);
        // incremental |M| (same scheme as the native driver): seeded once,
        // then carried via FIXMATCHING's piggybacked count
        let mut cardinality = init.cardinality();

        loop {
            if let Some(trip) = ctx.checkpoint() {
                stats.device_cycles = clock.cycles;
                stats.device_parallel_cycles = clock.parallel_cycles;
                return Ok(RunResult { matching: state.to_matching(), stats, outcome: trip });
            }
            // host INITBFSARRAY equivalents on padded buffers
            let mut bfs: Vec<i32> = (0..art.nc)
                .map(|c| {
                    if c < g.nc && state.cmatch[c] > -1 {
                        L0 - 1
                    } else if c < g.nc {
                        L0
                    } else {
                        L0 - 1 // padding columns: never frontier
                    }
                })
                .collect();
            let mut rmatch = pad_i32(&state.rmatch, art.nr, -1);
            let mut pred = vec![-1i32; art.nr];
            let mut level = L0;
            let mut launches = 0u32;
            let mut aug_found = false;
            loop {
                let outs = exe.run_i32(&[
                    (&adj, &[art.nc as i64, art.k as i64]),
                    (&bfs, &[art.nc as i64]),
                    (&rmatch, &[art.nr as i64]),
                    (&pred, &[art.nr as i64]),
                    (&[level][..], &[]),
                ])?;
                launches += 1;
                let [b2, rm2, p2, vi, aug]: &[Vec<i32>; 5] = outs
                    .as_slice()
                    .try_into()
                    .map_err(|_| anyhow!("expected 5 outputs, got {}", outs.len()))?;
                bfs = b2.clone();
                rmatch = rm2.clone();
                pred = p2.clone();
                aug_found |= aug.first().copied().unwrap_or(0) != 0;
                if vi.first().copied().unwrap_or(0) == 0 {
                    break; // APFB: run to the bottom
                }
                level += 1;
            }
            stats.record_phase(launches);
            if !aug_found {
                break;
            }
            // pull device results back into the host state and finish the
            // phase with the simulator's ALTERNATE + FIXMATCHING
            state.rmatch.copy_from_slice(&rmatch[..g.nr]);
            state.predecessor.copy_from_slice(&pred[..g.nr]);
            let before = cardinality;
            alternate(&mut state, cfg, None, &mut clock);
            let (fixes, after) = fixmatching(&mut state, cfg, &mut clock);
            stats.fixes += fixes;
            let after = after as usize;
            cardinality = after;
            stats.augmentations += after.saturating_sub(before) as u64;
            if after <= before {
                // same safety net as the native driver
                let m = state.to_matching();
                let tail = crate::seq::Hk.run(g, m, &mut ctx.fork());
                stats.fallbacks += 1;
                stats.device_cycles = clock.cycles;
                stats.device_parallel_cycles = clock.parallel_cycles;
                return Ok(RunResult { matching: tail.matching, stats, outcome: tail.outcome });
            }
        }
        stats.device_cycles = clock.cycles;
        stats.device_parallel_cycles = clock.parallel_cycles;
        Ok(RunResult::with_stats(state.to_matching(), stats))
    }
}

impl MatchingAlgorithm for XlaHybridMatcher {
    fn name(&self) -> String {
        "xla:bfs-level-hybrid".into()
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        match self.try_run_ctx(g, &init, &mut ctx.fork()) {
            Ok(r) => r,
            Err(e) => {
                log::warn!("xla hybrid unavailable ({e:#}); using native GPU simulator");
                let mut r = super::driver::GpuMatcher::default().run(g, init, &mut ctx.fork());
                r.stats.fallbacks += 1;
                r
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // artifact-dependent tests live in rust/tests/xla_roundtrip.rs; pure
    // helpers are covered here.
    use super::*;
    use crate::graph::from_edges;

    fn art(nc: usize, nr: usize, k: usize) -> Artifact {
        Artifact {
            name: "t".into(),
            kind: ArtifactKind::ApfbFull,
            file: "t.hlo.txt".into(),
            nc,
            nr,
            k,
        }
    }

    #[test]
    fn pack_pads_and_preserves() {
        let g = from_edges(3, 2, &[(0, 0), (2, 0), (1, 1)]);
        let adj = pack_for_bucket(&g, &art(4, 4, 2)).unwrap();
        assert_eq!(adj.len(), 8);
        assert_eq!(&adj[0..2], &[0, 2]); // c0
        assert_eq!(&adj[2..4], &[1, -1]); // c1
        assert_eq!(&adj[4..8], &[-1, -1, -1, -1]); // padding
    }

    #[test]
    fn pack_rejects_overflow() {
        let g = from_edges(3, 2, &[(0, 0), (1, 0), (2, 0)]);
        assert!(pack_for_bucket(&g, &art(4, 4, 2)).is_err()); // deg 3 > k 2
        assert!(pack_for_bucket(&g, &art(1, 4, 4)).is_err()); // nc 2 > 1
        assert!(pack_for_bucket(&g, &art(4, 2, 4)).is_err()); // nr 3 > 2
        assert!(pack_for_bucket(&g, &art(4, 4, 4)).is_ok());
    }

    #[test]
    fn pad_helper() {
        assert_eq!(pad_i32(&[1, 2], 4, -1), vec![1, 2, -1, -1]);
        assert_eq!(pad_i32(&[1, 2], 2, -1), vec![1, 2]);
    }
}
