//! The paper's contribution: GPU maximum-cardinality matching (APFB/APsB
//! drivers, GPUBFS/GPUBFS-WR kernels, ALTERNATE + FIXMATCHING speculative
//! augmentation), executed on a deterministic device simulator
//! ([`device`]) or through AOT-compiled XLA artifacts ([`xla_backend`]).
//!
//! Beyond the paper's eight variants, every driver supports
//! [`FrontierMode::Compacted`]: worklist-driven BFS sweeps whose per-launch
//! cost is `O(|frontier| + edges(frontier))` rather than the paper's
//! `O(nc)` full scan, plus an endpoint worklist that lets ALTERNATE skip
//! its `O(nr)` selection scan (named with an "-FC" suffix, e.g.
//! "APFB-GPUBFS-WR-CT-FC" — the coordinator router's default GPU pick),
//! and host-parallel execution of *all* kernels
//! (`GpuConfig::device_parallelism`): disjoint kernels bit-identically,
//! racy ones through the atomic CAS substrate in [`device`].

pub mod config;
pub mod device;
pub mod driver;
pub mod kernels;
pub mod xla_backend;

pub use config::{ApDriver, BfsKernel, FrontierMode, GpuConfig, ThreadMapping, WriteOrder};
pub use driver::GpuMatcher;
