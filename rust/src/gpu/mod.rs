//! The paper's contribution: GPU maximum-cardinality matching (APFB/APsB
//! drivers, GPUBFS/GPUBFS-WR kernels, ALTERNATE + FIXMATCHING speculative
//! augmentation), executed on a deterministic device simulator
//! ([`device`]) or through AOT-compiled XLA artifacts ([`xla_backend`]).

pub mod config;
pub mod device;
pub mod driver;
pub mod kernels;
pub mod xla_backend;

pub use config::{ApDriver, BfsKernel, GpuConfig, ThreadMapping, WriteOrder};
pub use driver::GpuMatcher;
