//! The paper's device kernels (Algorithms 2–4 plus the init and fix
//! kernels), executed on the [`super::device`] model.
//!
//! Each BFS kernel has a frontier-compacted twin (`*_frontier`) for
//! [`super::config::FrontierMode::Compacted`]: identical per-column body,
//! but the launch covers an explicit worklist and emits the next one —
//! plus the endpoint worklist (rows newly flagged `-2`) that lets the
//! compacted ALTERNATE skip its all-rows selection scan — so sparse late
//! levels stop paying the `O(nc)`/`O(nr)` scan floors.
//!
//! Every kernel runs host-parallel when `LaunchCfg::par_threads > 1`:
//! INITBFSARRAY and FIXMATCHING (per-index-disjoint writes) keep modeled
//! cycles bit-identical to serial, while the racy kernels — GPUBFS,
//! GPUBFS-WR, their frontier twins, and ALTERNATE — go through the
//! atomic substrate ([`crate::util::pool::AtomicCells`], CAS claims
//! charged [`CAS_COST`]). Claim winners then depend on the host schedule,
//! which is one legal serialization of the CUDA race: the per-level claim
//! *sets* stay deterministic for GPUBFS, and the final matching
//! cardinality is schedule-independent for all of them (FIXMATCHING plus
//! the driver's safety net absorb any interleaving).
//!
//! All array/sentinel conventions match the paper exactly:
//! * `rmatch[r] = -1` unmatched, `-2` = endpoint of a discovered
//!   augmenting path (set by the BFS kernels, consumed by ALTERNATE).
//! * `bfs_array[c] = L0-1` for matched (unvisited) columns, `L0` for
//!   unmatched columns (BFS start level), `level+1` when claimed.
//! * GPUBFS-WR: `bfs_array[root] < L0-1` marks a satisfied root. With
//!   `L0 = 2`, live levels are positive, so the APsB improvement encodes
//!   the chosen endpoint row as a non-positive value. (We store
//!   `-(row+1)`, not the paper's `-(row)`: row 0 would collide with the
//!   plain "satisfied" marker `L0-2 = 0` — an off-by-one latent in the
//!   paper's description.)

use super::config::{ThreadMapping, WriteOrder, WARP_SIZE};
use super::device::{
    charge_uniform_scan, launch, launch_frontier, launch_frontier_parallel, launch_parallel,
    launch_parallel_racy, DeviceClock, StepPlan, WarpStepper, CAS_COST, COMPACTION_COST,
    EDGE_COST, ITEM_COST, WARP_COST,
};
use crate::graph::csr::BipartiteCsr;
use crate::matching::Matching;
use crate::util::pool::{fork_join, AtomicCells, SharedSlice};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// BFS start level. The paper's APsB-GPUBFS-WR improvement requires
/// `L0 = 2` so that `bfs_array` stays positive for live levels.
pub const L0: i32 = 2;

/// Device-resident state for one matching computation.
#[derive(Debug, Clone)]
pub struct GpuState {
    pub bfs_array: Vec<i32>,
    pub predecessor: Vec<i32>,
    pub root: Vec<i32>,
    pub rmatch: Vec<i32>,
    pub cmatch: Vec<i32>,
    pub vertex_inserted: bool,
    pub augmenting_path_found: bool,
    /// per-item work record for the racy parallel executors
    /// ([`super::device::launch_parallel_racy`] and the frontier twin):
    /// kept on the state so one buffer serves every launch of the run
    /// (and, when leased via [`GpuState::new_in`], every run sharing the
    /// pool) instead of a fresh `vec![0u64; n]` per launch. Serial runs
    /// never touch it.
    pub work: Vec<u64>,
}

impl GpuState {
    pub fn new(g: &BipartiteCsr, init: &Matching) -> Self {
        Self {
            bfs_array: vec![0; g.nc],
            predecessor: vec![-1; g.nr],
            root: vec![-1; g.nc],
            rmatch: init.rmatch.clone(),
            cmatch: init.cmatch.clone(),
            vertex_inserted: false,
            augmenting_path_found: false,
            work: Vec::new(),
        }
    }

    /// Like [`GpuState::new`], but the per-run device arrays come from a
    /// [`WorkspacePool`] lease — the driver's path, so worker threads stop
    /// re-allocating `bfs_array`/`predecessor`/`root` on every job. Pair
    /// with [`GpuState::release`].
    pub fn new_in(
        g: &BipartiteCsr,
        init: &Matching,
        pool: &crate::util::pool::WorkspacePool,
    ) -> Self {
        Self {
            bfs_array: pool.lease_i32(g.nc, 0),
            predecessor: pool.lease_i32(g.nr, -1),
            root: pool.lease_i32(g.nc, -1),
            rmatch: init.rmatch.clone(),
            cmatch: init.cmatch.clone(),
            vertex_inserted: false,
            augmenting_path_found: false,
            // cap hint 0: serial runs stay allocation-free, parallel runs
            // grow it once and the capacity then circulates via the shelf
            work: pool.lease_u64_worklist(0),
        }
    }

    /// Give the leased device arrays back to `pool` and move the matching
    /// out (must be called only after FIXMATCHING, like
    /// [`GpuState::to_matching`]).
    pub fn release(self, pool: &crate::util::pool::WorkspacePool) -> Matching {
        pool.give_i32(self.bfs_array);
        pool.give_i32(self.predecessor);
        pool.give_i32(self.root);
        pool.give_u64(self.work);
        Matching { rmatch: self.rmatch, cmatch: self.cmatch }
    }

    pub fn cardinality(&self) -> usize {
        self.cmatch.iter().filter(|&&r| r >= 0).count()
    }

    /// Extract a host [`Matching`] (must be called only after FIXMATCHING;
    /// sentinels would fail validation).
    pub fn to_matching(&self) -> Matching {
        Matching { rmatch: self.rmatch.clone(), cmatch: self.cmatch.clone() }
    }
}

/// Kernel launch parameters shared by every kernel in one run.
#[derive(Debug, Clone, Copy)]
pub struct LaunchCfg {
    pub mapping: ThreadMapping,
    pub order: WriteOrder,
    pub seed: u64,
    /// host threads for the per-item-disjoint kernels (INITBFSARRAY,
    /// FIXMATCHING); 1 = serial. Modeled cycles and results are identical
    /// for every value.
    pub par_threads: usize,
}

impl Default for LaunchCfg {
    fn default() -> Self {
        Self { mapping: ThreadMapping::Ct, order: WriteOrder::Forward, seed: 0, par_threads: 1 }
    }
}

/// INITBFSARRAY (§3): `bfs_array[c] = L0-1` if matched else `L0`; also
/// resets per-phase arrays (predecessor; root when `with_root`). Writes
/// are per-index disjoint, so `cfg.par_threads > 1` executes on the host
/// pool via [`launch_parallel`] — same result, same modeled cycles, less
/// wall-clock.
pub fn init_bfs_array(state: &mut GpuState, cfg: LaunchCfg, with_root: bool, clock: &mut DeviceClock) {
    let nc = state.cmatch.len();
    if cfg.par_threads > 1 {
        {
            let cmatch: &[i32] = &state.cmatch;
            let bfs = SharedSlice::new(&mut state.bfs_array);
            let rootw = SharedSlice::new(&mut state.root);
            launch_parallel(clock, cfg.mapping, "INITBFSARRAY", nc, cfg.par_threads, |c| {
                // SAFETY: each index `c` is written by exactly one thread.
                unsafe {
                    if cmatch[c] > -1 {
                        bfs.set(c, L0 - 1);
                        if with_root {
                            rootw.set(c, -1);
                        }
                    } else {
                        bfs.set(c, L0);
                        if with_root {
                            rootw.set(c, c as i32);
                        }
                    }
                }
            });
        }
        let nr = state.predecessor.len();
        let pred = SharedSlice::new(&mut state.predecessor);
        launch_parallel(clock, cfg.mapping, "INITBFSARRAY", nr, cfg.par_threads, |r| {
            // SAFETY: disjoint per-index writes.
            unsafe { pred.set(r, -1) }
        });
        return;
    }
    let cmatch = &state.cmatch;
    let bfs_array = &mut state.bfs_array;
    let root = &mut state.root;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
        if cmatch[c] > -1 {
            bfs_array[c] = L0 - 1;
            if with_root {
                root[c] = -1;
            }
        } else {
            bfs_array[c] = L0;
            if with_root {
                root[c] = c as i32;
            }
        }
        0
    });
    let nr = state.predecessor.len();
    let predecessor = &mut state.predecessor;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
        predecessor[r] = -1;
        0
    });
}

/// INITBFSARRAY for [`super::config::FrontierMode::Compacted`]: the same
/// per-column init as [`init_bfs_array`], additionally emitting the
/// initial frontier (every unmatched column) into `frontier` (cleared
/// first, so the driver's buffer and its capacity are reused every phase).
/// The appends are charged [`COMPACTION_COST`] apiece on top of the scan.
/// Runs serially regardless of `par_threads` so the worklist order — which
/// seeds the simulated write races downstream — is deterministic.
pub fn init_bfs_array_frontier(
    state: &mut GpuState,
    cfg: LaunchCfg,
    with_root: bool,
    frontier: &mut Vec<u32>,
    clock: &mut DeviceClock,
) {
    let nc = state.cmatch.len();
    frontier.clear();
    {
        let cmatch = &state.cmatch;
        let bfs_array = &mut state.bfs_array;
        let root = &mut state.root;
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
            if cmatch[c] > -1 {
                bfs_array[c] = L0 - 1;
                if with_root {
                    root[c] = -1;
                }
            } else {
                bfs_array[c] = L0;
                if with_root {
                    root[c] = c as i32;
                }
                frontier.push(c as u32);
            }
            0
        });
    }
    // bulk charge for building the initial worklist
    clock.charge_warp_work(frontier.len() as u64 * COMPACTION_COST, 0);
    let nr = state.predecessor.len();
    let predecessor = &mut state.predecessor;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
        predecessor[r] = -1;
        0
    });
}

/// INITBFSARRAY for a *seeded* repair phase (`dynamic::repair`): instead
/// of activating every unmatched column, only the given `seeds` (the
/// columns a delta batch exposed) enter the BFS at `L0` — every other
/// column, matched or not, starts at `L0 - 1` so the sweeps never expand
/// it. Works in both frontier modes: under
/// [`super::config::FrontierMode::Compacted`] pass `frontier` to receive
/// the seed worklist (cleared first); under FullScan pass `None` and the
/// full-scan kernels simply find no non-seed column at `L0`. Seeds that
/// are out of range or already matched are skipped; duplicates are
/// idempotent (the `bfs_array` check). Activations are charged
/// [`COMPACTION_COST`] apiece on top of the reset scan. Serial regardless
/// of `par_threads`, like [`init_bfs_array_frontier`], so worklist order
/// is deterministic.
pub fn init_bfs_array_seeded(
    state: &mut GpuState,
    cfg: LaunchCfg,
    with_root: bool,
    seeds: &[u32],
    mut frontier: Option<&mut Vec<u32>>,
    clock: &mut DeviceClock,
) {
    let nc = state.cmatch.len();
    if let Some(f) = frontier.as_deref_mut() {
        f.clear();
    }
    {
        let bfs_array = &mut state.bfs_array;
        let root = &mut state.root;
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
            bfs_array[c] = L0 - 1;
            if with_root {
                root[c] = -1;
            }
            0
        });
    }
    let mut activated = 0u64;
    {
        let GpuState { bfs_array, root, cmatch, .. } = &mut *state;
        for &c in seeds {
            let c = c as usize;
            if c < nc && cmatch[c] == -1 && bfs_array[c] != L0 {
                bfs_array[c] = L0;
                if with_root {
                    root[c] = c as i32;
                }
                if let Some(f) = frontier.as_deref_mut() {
                    f.push(c as u32);
                }
                activated += 1;
            }
        }
    }
    clock.charge_warp_work(activated * COMPACTION_COST, 0);
    let nr = state.predecessor.len();
    let predecessor = &mut state.predecessor;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
        predecessor[r] = -1;
        0
    });
}

/// GPUBFS — Algorithm 2: one level expansion over all columns. With
/// `cfg.par_threads > 1` the expansion runs host-parallel under the
/// atomic substrate (level claims via CAS, charged [`CAS_COST`]); the
/// set of columns claimed per level is the same as serial — only which
/// frontier column wins a claim (the `predecessor` entry) is decided by
/// the race.
pub fn gpubfs(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    if cfg.par_threads > 1 {
        return gpubfs_par(g, state, bfs_level, cfg, clock);
    }
    let mut edges_total = 0u64;
    let GpuState { bfs_array, predecessor, rmatch, vertex_inserted, augmenting_path_found, .. } =
        state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, g.nc, |col_vertex| {
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    predecessor[neighbor_row] = col_vertex as i32;
                }
            } else if col_match == -1 {
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// Host-parallel GPUBFS: the same per-column body as [`gpubfs`], with the
/// two racy writes turned into atomic claims — `bfs_array[col_match]`
/// moves `L0-1 → level+1` via CAS (exactly one thread wins and writes the
/// predecessor), and `rmatch[row]` moves `-1 → -2` via CAS (the winner
/// records the endpoint's predecessor). Mirrors the serial first-visitor-
/// wins semantics; losers pay the CAS and move on.
fn gpubfs_par(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    let GpuState {
        bfs_array, predecessor, rmatch, vertex_inserted, augmenting_path_found, work, ..
    } = state;
    let edges_total = AtomicU64::new(0);
    let vi = AtomicBool::new(false);
    let apf = AtomicBool::new(false);
    {
        let bfs = AtomicCells::new(bfs_array);
        let pred = AtomicCells::new(predecessor);
        let rm = AtomicCells::new(rmatch);
        launch_parallel_racy(
            clock,
            cfg.mapping,
            "GPUBFS",
            g.nc,
            cfg.par_threads,
            work,
            |_tid, col_vertex| {
                if bfs.load(col_vertex) != bfs_level {
                    return 0;
                }
                let mut edges = 0u64;
                let mut work = 0u64;
                for &nr in g.col_neighbors(col_vertex) {
                    edges += 1;
                    work += EDGE_COST;
                    let neighbor_row = nr as usize;
                    let col_match = rm.load(neighbor_row);
                    if col_match > -1 {
                        if bfs.load(col_match as usize) == L0 - 1 {
                            work += CAS_COST;
                            if bfs.cas(col_match as usize, L0 - 1, bfs_level + 1) {
                                vi.store(true, Ordering::Relaxed);
                                pred.store(neighbor_row, col_vertex as i32);
                            }
                        }
                    } else if col_match == -1 {
                        work += CAS_COST;
                        if rm.cas(neighbor_row, -1, -2) {
                            pred.store(neighbor_row, col_vertex as i32);
                            apf.store(true, Ordering::Relaxed);
                        }
                    }
                }
                edges_total.fetch_add(edges, Ordering::Relaxed);
                work
            },
        );
    }
    *vertex_inserted |= vi.into_inner();
    *augmenting_path_found |= apf.into_inner();
    edges_total.into_inner()
}

/// GPUBFS over an explicit frontier ([`super::config::FrontierMode::Compacted`]):
/// the same per-column body as [`gpubfs`], but the launch covers only the
/// live columns of this level, appends each newly claimed column to
/// `next`, and appends each newly flagged endpoint row (`rmatch → -2`) to
/// `endpoints` — the worklist the compacted ALTERNATE consumes instead of
/// scanning all rows. Per-launch work is `O(|frontier| + edges(frontier))`
/// instead of `O(nc)`. Appends are charged [`COMPACTION_COST`], edge
/// scans [`EDGE_COST`]. Returns edges scanned.
#[allow(clippy::too_many_arguments)]
pub fn gpubfs_frontier(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    frontier: &[u32],
    next: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    if cfg.par_threads > 1 {
        return gpubfs_frontier_par(g, state, bfs_level, frontier, next, endpoints, cfg, clock);
    }
    let mut edges_total = 0u64;
    let GpuState { bfs_array, predecessor, rmatch, vertex_inserted, augmenting_path_found, .. } =
        state;
    launch_frontier(clock, cfg.mapping, cfg.order, cfg.seed, frontier, |col_vertex| {
        debug_assert_eq!(bfs_array[col_vertex], bfs_level, "stale frontier entry");
        let mut edges = 0u64;
        let mut appended = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    predecessor[neighbor_row] = col_vertex as i32;
                    next.push(col_match as u32);
                    appended += 1;
                }
            } else if col_match == -1 {
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
                endpoints.push(neighbor_row as u32);
                appended += 1;
            }
        }
        edges_total += edges;
        edges * EDGE_COST + appended * COMPACTION_COST
    });
    edges_total
}

/// Per-host-thread output buffers for the parallel frontier kernels; one
/// slot per host thread, merged into the shared worklists in thread-id
/// order after the join so the merge is deterministic given the claim
/// outcomes.
#[derive(Default)]
struct FrontierBufs {
    next: Vec<u32>,
    endpoints: Vec<u32>,
}

fn merge_frontier_bufs(bufs: Vec<FrontierBufs>, next: &mut Vec<u32>, endpoints: &mut Vec<u32>) {
    for b in bufs {
        next.extend_from_slice(&b.next);
        endpoints.extend_from_slice(&b.endpoints);
    }
}

/// Host-parallel frontier GPUBFS: CAS level claims as in [`gpubfs_par`],
/// with claimed columns / flagged endpoints appended to per-thread
/// buffers and merged by host-thread id.
#[allow(clippy::too_many_arguments)]
fn gpubfs_frontier_par(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    frontier: &[u32],
    next: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    let nthreads = cfg.par_threads.max(1);
    let mut bufs: Vec<FrontierBufs> = (0..nthreads).map(|_| FrontierBufs::default()).collect();
    let edges_total = AtomicU64::new(0);
    let vi = AtomicBool::new(false);
    let apf = AtomicBool::new(false);
    {
        let GpuState { bfs_array, predecessor, rmatch, work, .. } = state;
        let bfs = AtomicCells::new(bfs_array);
        let pred = AtomicCells::new(predecessor);
        let rm = AtomicCells::new(rmatch);
        let out = SharedSlice::new(&mut bufs);
        launch_frontier_parallel(
            clock,
            cfg.mapping,
            "GPUBFS-FRONTIER",
            frontier,
            nthreads,
            work,
            |tid, col_vertex| {
                debug_assert_eq!(bfs.load(col_vertex), bfs_level, "stale frontier entry");
                let mut edges = 0u64;
                let mut work = 0u64;
                for &nr in g.col_neighbors(col_vertex) {
                    edges += 1;
                    work += EDGE_COST;
                    let neighbor_row = nr as usize;
                    let col_match = rm.load(neighbor_row);
                    if col_match > -1 {
                        if bfs.load(col_match as usize) == L0 - 1 {
                            work += CAS_COST;
                            if bfs.cas(col_match as usize, L0 - 1, bfs_level + 1) {
                                vi.store(true, Ordering::Relaxed);
                                pred.store(neighbor_row, col_vertex as i32);
                                // SAFETY: slot `tid` is only touched by this
                                // host thread.
                                unsafe { out.get_lane_mut(tid) }.next.push(col_match as u32);
                                work += COMPACTION_COST;
                            }
                        }
                    } else if col_match == -1 {
                        work += CAS_COST;
                        if rm.cas(neighbor_row, -1, -2) {
                            pred.store(neighbor_row, col_vertex as i32);
                            apf.store(true, Ordering::Relaxed);
                            // SAFETY: slot `tid` is only touched by this host
                            // thread.
                            unsafe { out.get_lane_mut(tid) }.endpoints.push(neighbor_row as u32);
                            work += COMPACTION_COST;
                        }
                    }
                }
                edges_total.fetch_add(edges, Ordering::Relaxed);
                work
            },
        );
    }
    merge_frontier_bufs(bufs, next, endpoints);
    state.vertex_inserted |= vi.into_inner();
    state.augmenting_path_found |= apf.into_inner();
    edges_total.into_inner()
}

/// GPUBFS-WR — Algorithm 4: level expansion carrying the `root` array,
/// with early exit for satisfied roots. `encode_endpoint` enables the
/// APsB improvement (store the chosen endpoint row in the root's
/// `bfs_array` slot).
pub fn gpubfs_wr(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    if cfg.par_threads > 1 {
        return gpubfs_wr_par(g, state, bfs_level, cfg, encode_endpoint, clock);
    }
    let mut edges_total = 0u64;
    let GpuState {
        bfs_array,
        predecessor,
        root,
        rmatch,
        vertex_inserted,
        augmenting_path_found,
        ..
    } = state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, g.nc, |col_vertex| {
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let my_root = root[col_vertex];
        debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
        if bfs_array[my_root as usize] < L0 - 1 {
            return 0; // early exit: this tree already found a path
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    root[col_match as usize] = my_root;
                    predecessor[neighbor_row] = col_vertex as i32;
                }
            } else if col_match == -1 {
                bfs_array[my_root as usize] = if encode_endpoint {
                    -(neighbor_row as i32 + 1)
                } else {
                    L0 - 2
                };
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// Host-parallel GPUBFS-WR: [`gpubfs_wr`]'s body under the atomic
/// substrate. Level claims and endpoint flags go through CAS as in
/// [`gpubfs_par`]; the claim winner also installs the root. The
/// endpoint encoding (`bfs_array[root] ← -(row+1)`) is a plain racy
/// store whose last writer wins — the same arbitration the serial
/// write orders enumerate — and the satisfied-tree early exit reads
/// whatever encoding is visible, which only ever *prunes* work the
/// serial schedule might still have done.
fn gpubfs_wr_par(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    let GpuState {
        bfs_array,
        predecessor,
        root,
        rmatch,
        vertex_inserted,
        augmenting_path_found,
        work,
        ..
    } = state;
    let edges_total = AtomicU64::new(0);
    let vi = AtomicBool::new(false);
    let apf = AtomicBool::new(false);
    {
        let bfs = AtomicCells::new(bfs_array);
        let pred = AtomicCells::new(predecessor);
        let rt = AtomicCells::new(root);
        let rm = AtomicCells::new(rmatch);
        launch_parallel_racy(
            clock,
            cfg.mapping,
            "GPUBFS-WR",
            g.nc,
            cfg.par_threads,
            work,
            |_tid, col_vertex| {
                if bfs.load(col_vertex) != bfs_level {
                    return 0;
                }
                let my_root = rt.load(col_vertex);
                debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
                if bfs.load(my_root as usize) < L0 - 1 {
                    return 0; // early exit: this tree already found a path
                }
                let mut edges = 0u64;
                let mut work = 0u64;
                for &nr in g.col_neighbors(col_vertex) {
                    edges += 1;
                    work += EDGE_COST;
                    let neighbor_row = nr as usize;
                    let col_match = rm.load(neighbor_row);
                    if col_match > -1 {
                        if bfs.load(col_match as usize) == L0 - 1 {
                            work += CAS_COST;
                            if bfs.cas(col_match as usize, L0 - 1, bfs_level + 1) {
                                vi.store(true, Ordering::Relaxed);
                                rt.store(col_match as usize, my_root);
                                pred.store(neighbor_row, col_vertex as i32);
                            }
                        }
                    } else if col_match == -1 {
                        work += CAS_COST;
                        if rm.cas(neighbor_row, -1, -2) {
                            pred.store(neighbor_row, col_vertex as i32);
                            bfs.store(
                                my_root as usize,
                                if encode_endpoint { -(neighbor_row as i32 + 1) } else { L0 - 2 },
                            );
                            apf.store(true, Ordering::Relaxed);
                        }
                    }
                }
                edges_total.fetch_add(edges, Ordering::Relaxed);
                work
            },
        );
    }
    *vertex_inserted |= vi.into_inner();
    *augmenting_path_found |= apf.into_inner();
    edges_total.into_inner()
}

/// GPUBFS-WR over an explicit frontier: [`gpubfs_wr`]'s body (root
/// carrying, satisfied-tree early exit, optional endpoint encoding) on a
/// compacted worklist, appending claimed columns to `next` and newly
/// flagged endpoint rows to `endpoints`. Returns edges scanned.
#[allow(clippy::too_many_arguments)]
pub fn gpubfs_wr_frontier(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    frontier: &[u32],
    next: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    if cfg.par_threads > 1 {
        return gpubfs_wr_frontier_par(
            g,
            state,
            bfs_level,
            frontier,
            next,
            endpoints,
            cfg,
            encode_endpoint,
            clock,
        );
    }
    let mut edges_total = 0u64;
    let GpuState {
        bfs_array,
        predecessor,
        root,
        rmatch,
        vertex_inserted,
        augmenting_path_found,
        ..
    } = state;
    launch_frontier(clock, cfg.mapping, cfg.order, cfg.seed, frontier, |col_vertex| {
        debug_assert_eq!(bfs_array[col_vertex], bfs_level, "stale frontier entry");
        let my_root = root[col_vertex];
        debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
        if bfs_array[my_root as usize] < L0 - 1 {
            return 0; // early exit: this tree already found a path
        }
        let mut edges = 0u64;
        let mut appended = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    root[col_match as usize] = my_root;
                    predecessor[neighbor_row] = col_vertex as i32;
                    next.push(col_match as u32);
                    appended += 1;
                }
            } else if col_match == -1 {
                bfs_array[my_root as usize] = if encode_endpoint {
                    -(neighbor_row as i32 + 1)
                } else {
                    L0 - 2
                };
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
                endpoints.push(neighbor_row as u32);
                appended += 1;
            }
        }
        edges_total += edges;
        edges * EDGE_COST + appended * COMPACTION_COST
    });
    edges_total
}

/// Host-parallel frontier GPUBFS-WR: [`gpubfs_wr_par`]'s atomic claims on
/// a compacted worklist, with per-thread output buffers merged by
/// host-thread id.
#[allow(clippy::too_many_arguments)]
fn gpubfs_wr_frontier_par(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    frontier: &[u32],
    next: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    let nthreads = cfg.par_threads.max(1);
    let mut bufs: Vec<FrontierBufs> = (0..nthreads).map(|_| FrontierBufs::default()).collect();
    let edges_total = AtomicU64::new(0);
    let vi = AtomicBool::new(false);
    let apf = AtomicBool::new(false);
    {
        let GpuState { bfs_array, predecessor, root, rmatch, work, .. } = state;
        let bfs = AtomicCells::new(bfs_array);
        let pred = AtomicCells::new(predecessor);
        let rt = AtomicCells::new(root);
        let rm = AtomicCells::new(rmatch);
        let out = SharedSlice::new(&mut bufs);
        launch_frontier_parallel(
            clock,
            cfg.mapping,
            "GPUBFS-WR-FRONTIER",
            frontier,
            nthreads,
            work,
            |tid, col_vertex| {
                debug_assert_eq!(bfs.load(col_vertex), bfs_level, "stale frontier entry");
                let my_root = rt.load(col_vertex);
                debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
                if bfs.load(my_root as usize) < L0 - 1 {
                    return 0; // early exit: this tree already found a path
                }
                let mut edges = 0u64;
                let mut work = 0u64;
                for &nr in g.col_neighbors(col_vertex) {
                    edges += 1;
                    work += EDGE_COST;
                    let neighbor_row = nr as usize;
                    let col_match = rm.load(neighbor_row);
                    if col_match > -1 {
                        if bfs.load(col_match as usize) == L0 - 1 {
                            work += CAS_COST;
                            if bfs.cas(col_match as usize, L0 - 1, bfs_level + 1) {
                                vi.store(true, Ordering::Relaxed);
                                rt.store(col_match as usize, my_root);
                                pred.store(neighbor_row, col_vertex as i32);
                                // SAFETY: slot `tid` is only touched by this
                                // host thread.
                                unsafe { out.get_lane_mut(tid) }.next.push(col_match as u32);
                                work += COMPACTION_COST;
                            }
                        }
                    } else if col_match == -1 {
                        work += CAS_COST;
                        if rm.cas(neighbor_row, -1, -2) {
                            pred.store(neighbor_row, col_vertex as i32);
                            bfs.store(
                                my_root as usize,
                                if encode_endpoint { -(neighbor_row as i32 + 1) } else { L0 - 2 },
                            );
                            apf.store(true, Ordering::Relaxed);
                            // SAFETY: slot `tid` is only touched by this host
                            // thread.
                            unsafe { out.get_lane_mut(tid) }.endpoints.push(neighbor_row as u32);
                            work += COMPACTION_COST;
                        }
                    }
                }
                edges_total.fetch_add(edges, Ordering::Relaxed);
                work
            },
        );
    }
    merge_frontier_bufs(bufs, next, endpoints);
    state.vertex_inserted |= vi.into_inner();
    state.augmenting_path_found |= apf.into_inner();
    edges_total.into_inner()
}

/// GPUBFS restricted to a contiguous column range — the per-shard
/// full-scan sweep of sharded execution (`crate::shard`): shard `s` scans
/// only the columns it owns, so the `O(nc)` scan floor splits K ways.
/// The body is [`gpubfs`]'s exactly; additionally every claimed column is
/// appended to `claims` and every newly flagged endpoint row to
/// `endpoints` — *host-side exchange accounting*, not device worklists
/// (no [`COMPACTION_COST`] is charged; cross-shard routing of these items
/// is priced by the interconnect constants in `gpu::device`). Runs
/// serially regardless of `cfg.par_threads`: under sharding the shards
/// themselves are the parallelism axis. Returns edges scanned.
#[allow(clippy::too_many_arguments)]
pub fn gpubfs_cols(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cols: std::ops::Range<usize>,
    claims: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    let mut edges_total = 0u64;
    let lo = cols.start;
    let n_local = cols.end.saturating_sub(lo);
    let GpuState { bfs_array, predecessor, rmatch, vertex_inserted, augmenting_path_found, .. } =
        state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, n_local, |idx| {
        let col_vertex = lo + idx;
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    predecessor[neighbor_row] = col_vertex as i32;
                    claims.push(col_match as u32);
                }
            } else if col_match == -1 {
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
                endpoints.push(neighbor_row as u32);
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// GPUBFS-WR restricted to a contiguous column range — [`gpubfs_cols`]'s
/// root-carrying twin (body of [`gpubfs_wr`], incl. the satisfied-tree
/// early exit and the APsB endpoint encoding). Claimed columns go to
/// `claims`, flagged endpoint rows to `endpoints`, both for exchange
/// accounting only. Note a claim or endpoint encode may touch a column
/// owned by another shard (trees cross partition boundaries); the routed
/// item's word charge covers that update. Returns edges scanned.
#[allow(clippy::too_many_arguments)]
pub fn gpubfs_wr_cols(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cols: std::ops::Range<usize>,
    claims: &mut Vec<u32>,
    endpoints: &mut Vec<u32>,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    let mut edges_total = 0u64;
    let lo = cols.start;
    let n_local = cols.end.saturating_sub(lo);
    let GpuState {
        bfs_array,
        predecessor,
        root,
        rmatch,
        vertex_inserted,
        augmenting_path_found,
        ..
    } = state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, n_local, |idx| {
        let col_vertex = lo + idx;
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let my_root = root[col_vertex];
        debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
        if bfs_array[my_root as usize] < L0 - 1 {
            return 0; // early exit: this tree already found a path
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    root[col_match as usize] = my_root;
                    predecessor[neighbor_row] = col_vertex as i32;
                    claims.push(col_match as u32);
                }
            } else if col_match == -1 {
                bfs_array[my_root as usize] = if encode_endpoint {
                    -(neighbor_row as i32 + 1)
                } else {
                    L0 - 2
                };
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
                endpoints.push(neighbor_row as u32);
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// ALTERNATE — Algorithm 3, executed in intra-warp lockstep so the
/// paper's same-warp double-claim inconsistency actually occurs (and is
/// then repaired by FIXMATCHING). `only_rows` restricts the starting rows
/// (the WR variant's chosen endpoints, or the compacted endpoint worklist
/// the frontier BFS kernels emitted); `None` starts from every
/// `rmatch == -2` row, which on device means a kernel scanning all `nr`
/// rows — that selection scan is charged here (it rides inside the
/// ALTERNATE launch), and is exactly the cost
/// [`super::config::FrontierMode::Compacted`] eliminates by handing over
/// the worklist. With `cfg.par_threads > 1` the alternation runs
/// host-parallel and lock-free: column claims become atomic exchanges
/// (charged [`CAS_COST`]) instead of lockstep write-order arbitration.
pub fn alternate(
    state: &mut GpuState,
    cfg: LaunchCfg,
    only_rows: Option<&[u32]>,
    clock: &mut DeviceClock,
) {
    // thread payload: (current row_vertex, steps taken)
    let max_steps = (state.rmatch.len() + state.cmatch.len() + 2) as u32;
    let mut threads: Vec<(i32, u32)> = match only_rows {
        Some(rows) => rows.iter().map(|&r| (r as i32, 0)).collect(),
        None => {
            charge_uniform_scan(clock, cfg.mapping, state.rmatch.len());
            (0..state.rmatch.len())
                .filter(|&r| state.rmatch[r] == -2)
                .map(|r| (r as i32, 0))
                .collect()
        }
    };
    if cfg.par_threads > 1 {
        alternate_atomic(state, cfg, threads, max_steps, clock);
        return;
    }
    let stepper = WarpStepper { order: cfg.order, seed: cfg.seed };
    /// the memory the ALTERNATE kernel touches
    struct Mem<'a> {
        predecessor: &'a [i32],
        rmatch: &'a mut [i32],
        cmatch: &'a mut [i32],
    }
    let mut mem = Mem {
        predecessor: &state.predecessor,
        rmatch: &mut state.rmatch,
        cmatch: &mut state.cmatch,
    };
    stepper.run(
        clock,
        &mut threads,
        &mut mem,
        // read phase (one lockstep iteration of the while loop, lines 5–9)
        |mem, &(row_vertex, steps)| {
            if row_vertex < 0 || steps >= max_steps {
                return StepPlan::Done;
            }
            let matched_col = mem.predecessor[row_vertex as usize];
            if matched_col < 0 {
                return StepPlan::Done; // stale/cleared predecessor guard
            }
            let matched_row = mem.cmatch[matched_col as usize];
            // paper line 8: another alternation already claimed this column
            if matched_row > -1 && mem.predecessor[matched_row as usize] == matched_col {
                return StepPlan::Done;
            }
            StepPlan::Write((matched_col, matched_row))
        },
        // write phase (lines 10–12), applied in lane order
        |mem, t, (matched_col, matched_row)| {
            let (row_vertex, steps) = *t;
            mem.cmatch[matched_col as usize] = row_vertex;
            mem.rmatch[row_vertex as usize] = matched_col;
            *t = (matched_row, steps + 1);
            matched_row != -1
        },
    );
}

/// Host-parallel lock-free ALTERNATE: warps are distributed over host
/// threads in contiguous chunks; within a warp, lanes still advance in
/// lockstep rounds, but a lane's column claim is an atomic exchange —
/// `cmatch[col].swap(row)` hands the displaced row to exactly one thread,
/// which chases it, exactly the CAS discipline a real lock-free ALTERNATE
/// kernel uses. Each step charges `ITEM_COST + CAS_COST`; per-warp round
/// costs are recorded into per-warp slots and folded after the join so
/// the bill is a deterministic function of the steps actually taken.
fn alternate_atomic(
    state: &mut GpuState,
    cfg: LaunchCfg,
    mut threads: Vec<(i32, u32)>,
    max_steps: u32,
    clock: &mut DeviceClock,
) {
    clock.charge_launch();
    let n = threads.len();
    if n == 0 {
        return;
    }
    let n_warps = n.div_ceil(WARP_SIZE);
    let mut warp_cost = vec![0u64; n_warps];
    // This executor owns its own fork_join (warps, not items, are the unit
    // of host distribution), so it wires the race-sanitizer shadow scope
    // manually: the modeled "item" is the warp index — which matches the
    // per-warp cost vector the RMW cross-check runs against.
    let shadow = crate::sanitize::race::launch_scope("ALTERNATE");
    {
        let GpuState { predecessor, rmatch, cmatch, .. } = state;
        let pred = AtomicCells::new(predecessor);
        let rm = AtomicCells::new(rmatch);
        let cm = AtomicCells::new(cmatch);
        let costs = SharedSlice::new(&mut warp_cost);
        let payload = SharedSlice::new(&mut threads);
        let nthreads = cfg.par_threads.max(1);
        let per = n_warps.div_ceil(nthreads).max(1);
        fork_join(nthreads, |tid| {
            let _lane = shadow.as_ref().map(|s| s.enter(tid as u32));
            let wlo = (tid * per).min(n_warps);
            let whi = ((tid + 1) * per).min(n_warps);
            for w in wlo..whi {
                crate::sanitize::race::set_item(w as u32);
                let lo = w * WARP_SIZE;
                let hi = ((w + 1) * WARP_SIZE).min(n);
                let mut alive = vec![true; hi - lo];
                let mut cost = 0u64;
                loop {
                    // one lockstep round over this warp's live lanes
                    let mut round_work = 0u64;
                    for (k, i) in (lo..hi).enumerate() {
                        if !alive[k] {
                            continue;
                        }
                        round_work += ITEM_COST;
                        // SAFETY: payload `i` belongs to this warp, which
                        // is owned by this host thread.
                        let t = unsafe { payload.get_mut(i) };
                        let (row_vertex, steps) = *t;
                        if row_vertex < 0 || steps >= max_steps {
                            alive[k] = false;
                            continue;
                        }
                        let matched_col = pred.load(row_vertex as usize);
                        if matched_col < 0 {
                            alive[k] = false; // stale/cleared predecessor guard
                            continue;
                        }
                        let matched_row = cm.load(matched_col as usize);
                        // paper line 8: another alternation already
                        // claimed this column
                        if matched_row > -1 && pred.load(matched_row as usize) == matched_col {
                            alive[k] = false;
                            continue;
                        }
                        // lock-free claim (lines 10–12): exchange the
                        // column's row and chase whatever we displaced
                        round_work += CAS_COST;
                        let displaced = cm.swap(matched_col as usize, row_vertex);
                        rm.store(row_vertex as usize, matched_col);
                        *t = (displaced, steps + 1);
                        if displaced == -1 {
                            alive[k] = false; // free column: path realized
                        }
                    }
                    if round_work > 0 {
                        cost += WARP_COST + round_work;
                    }
                    if !alive.iter().any(|&a| a) {
                        break;
                    }
                }
                // SAFETY: slot `w` belongs to this host thread's chunk.
                unsafe { costs.set(w, cost) };
            }
        });
    }
    if let Some(s) = shadow {
        s.finish(
            crate::sanitize::race::CostCheck::PerItem {
                work: warp_cost.as_slice(),
                per_rmw: CAS_COST,
            },
            None,
        );
    }
    let warp_sum: u64 = warp_cost.iter().sum();
    let max_warp = warp_cost.iter().max().copied().unwrap_or(0);
    clock.charge_warp_work(warp_sum, max_warp);
}

/// The APsB-GPUBFS-WR chosen-endpoint predicate: row `r` alternates iff
/// it is flagged (`rmatch == -2`) and its root's `bfs_array` slot encodes
/// exactly `r` (the improvement stores `-(r+1)` there).
fn is_chosen_endpoint(state: &GpuState, r: usize) -> bool {
    if state.rmatch[r] != -2 {
        return false;
    }
    let c = state.predecessor[r];
    if c < 0 {
        return false;
    }
    let rt = state.root[c as usize];
    if rt < 0 {
        return false;
    }
    state.bfs_array[rt as usize] == -(r as i32 + 1)
}

/// Starting rows for the APsB-GPUBFS-WR improved ALTERNATE: only the row
/// encoded in its root's `bfs_array` slot alternates; every other
/// `rmatch == -2` row is left for FIXMATCHING to reset. Scans all rows —
/// the FullScan selection; callers in compacted mode should filter the
/// endpoint worklist via [`wr_chosen_endpoints_from`] instead.
pub fn wr_chosen_endpoints(state: &GpuState) -> Vec<u32> {
    (0..state.rmatch.len())
        .filter(|&r| is_chosen_endpoint(state, r))
        .map(|r| r as u32)
        .collect()
}

/// [`wr_chosen_endpoints`] restricted to the compacted endpoint worklist:
/// every `-2` row was appended to `endpoints` by the frontier BFS kernels
/// when it was flagged, so filtering the worklist is equivalent to the
/// all-rows scan at `O(|endpoints|)` cost.
pub fn wr_chosen_endpoints_from(state: &GpuState, endpoints: &[u32]) -> Vec<u32> {
    endpoints
        .iter()
        .copied()
        .filter(|&r| is_chosen_endpoint(state, r as usize))
        .collect()
}

/// FIXMATCHING (§3): clear leftover `-2` sentinels and dangling pointers,
/// keeping exactly the mutually-consistent pairs. Two passes: rows against
/// cmatch, then columns against the repaired rmatch. Returns
/// `(resets, cardinality)` — the second pass already scans every column,
/// so the post-repair matching cardinality rides along for free and the
/// driver needs no separate `O(nc)` count. Writes are per-index disjoint,
/// so `cfg.par_threads > 1` runs both passes on the host pool.
pub fn fixmatching(state: &mut GpuState, cfg: LaunchCfg, clock: &mut DeviceClock) -> (u64, u64) {
    if cfg.par_threads > 1 {
        return fixmatching_par(state, cfg, clock);
    }
    let mut fixes = 0u64;
    let mut matched = 0u64;
    {
        let GpuState { rmatch, cmatch, .. } = state;
        let nr = rmatch.len();
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
            let c = rmatch[r];
            if c == -2 || (c >= 0 && cmatch[c as usize] != r as i32) {
                rmatch[r] = -1;
                fixes += 1;
            }
            0
        });
    }
    {
        let GpuState { rmatch, cmatch, .. } = state;
        let nc = cmatch.len();
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
            let r = cmatch[c];
            if r >= 0 {
                if rmatch[r as usize] != c as i32 {
                    cmatch[c] = -1;
                    fixes += 1;
                } else {
                    matched += 1;
                }
            }
            0
        });
    }
    (fixes, matched)
}

/// Host-parallel FIXMATCHING: pass 1 writes only `rmatch[r]` (reads of
/// `cmatch` are un-mutated this pass), pass 2 writes only `cmatch[c]`
/// against the now-frozen `rmatch` — both per-index disjoint, with the
/// counters in atomics. Same `(resets, cardinality)` and modeled cycles
/// as the serial path.
fn fixmatching_par(state: &mut GpuState, cfg: LaunchCfg, clock: &mut DeviceClock) -> (u64, u64) {
    let fixes = AtomicU64::new(0);
    let matched = AtomicU64::new(0);
    {
        let cmatch: &[i32] = &state.cmatch;
        let nr = state.rmatch.len();
        let rm = SharedSlice::new(&mut state.rmatch);
        launch_parallel(clock, cfg.mapping, "FIXMATCHING", nr, cfg.par_threads, |r| {
            // SAFETY: only index `r` of rmatch is touched by this thread.
            unsafe {
                let c = rm.get(r);
                if c == -2 || (c >= 0 && cmatch[c as usize] != r as i32) {
                    rm.set(r, -1);
                    fixes.fetch_add(1, Ordering::Relaxed);
                }
            }
        });
    }
    {
        let rmatch: &[i32] = &state.rmatch;
        let nc = state.cmatch.len();
        let cm = SharedSlice::new(&mut state.cmatch);
        launch_parallel(clock, cfg.mapping, "FIXMATCHING", nc, cfg.par_threads, |c| {
            // SAFETY: only index `c` of cmatch is touched by this thread.
            unsafe {
                let r = cm.get(c);
                if r >= 0 {
                    if rmatch[r as usize] != c as i32 {
                        cm.set(c, -1);
                        fixes.fetch_add(1, Ordering::Relaxed);
                    } else {
                        matched.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        });
    }
    (fixes.into_inner(), matched.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::gpu::config::ThreadMapping;

    fn cfg() -> LaunchCfg {
        LaunchCfg { mapping: ThreadMapping::Mt, ..LaunchCfg::default() }
    }

    fn fresh(g: &BipartiteCsr, init: &Matching) -> (GpuState, DeviceClock) {
        (GpuState::new(g, init), DeviceClock::default())
    }

    #[test]
    fn init_bfs_array_levels() {
        let g = from_edges(2, 3, &[(0, 0), (1, 1), (0, 2)]);
        let mut init = Matching::empty(2, 3);
        init.join(1, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), true, &mut clock);
        assert_eq!(st.bfs_array, vec![L0, L0 - 1, L0]);
        assert_eq!(st.root, vec![0, -1, 2]);
        assert!(st.predecessor.iter().all(|&p| p == -1));
    }

    #[test]
    fn gpubfs_finds_direct_augmenting_path() {
        // unmatched c0 adjacent to free r0
        let g = from_edges(1, 1, &[(0, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(1, 1));
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.rmatch[0], -2);
        assert_eq!(st.predecessor[0], 0);
    }

    #[test]
    fn gpubfs_expands_through_matched_rows() {
        // c0 free, r0 matched to c1, r1 free: c0-r0 forces c1 into level 3
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        assert!(!st.augmenting_path_found);
        assert!(st.vertex_inserted);
        assert_eq!(st.bfs_array[1], L0 + 1);
        st.vertex_inserted = false;
        gpubfs(&g, &mut st, L0 + 1, cfg(), &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.rmatch[1], -2);
        assert_eq!(st.predecessor[1], 1);
    }

    #[test]
    fn gpubfs_wr_early_exit_stops_tree() {
        // two columns in the same tree; after the root is satisfied the
        // other column must not expand.
        let g = from_edges(3, 2, &[(0, 0), (1, 0), (1, 1), (2, 1)]);
        let mut init = Matching::empty(3, 2);
        init.join(1, 1); // c1 matched to r1
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), true, &mut clock);
        // level L0: c0 frontier; finds free r0 -> root satisfied
        gpubfs_wr(&g, &mut st, L0, cfg(), false, &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.bfs_array[0], L0 - 2);
        // c1 was claimed into the frontier at L0+1 under root 0
        assert_eq!(st.root[1], 0);
        let scanned = gpubfs_wr(&g, &mut st, L0 + 1, cfg(), false, &mut clock);
        assert_eq!(scanned, 0, "satisfied tree must not expand");
    }

    #[test]
    fn alternate_realizes_simple_path() {
        let g = from_edges(1, 1, &[(0, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(1, 1));
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        alternate(&mut st, cfg(), None, &mut clock);
        fixmatching(&mut st, cfg(), &mut clock);
        assert_eq!(st.rmatch, vec![0]);
        assert_eq!(st.cmatch, vec![0]);
        st.to_matching().certify(&g).unwrap();
    }

    #[test]
    fn alternate_flips_length3_path() {
        // c0 - r0 = c1 - r1 (c0 free, r1 free; r0 matched to c1)
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        gpubfs(&g, &mut st, L0 + 1, cfg(), &mut clock);
        alternate(&mut st, cfg(), None, &mut clock);
        let (fixes, card) = fixmatching(&mut st, cfg(), &mut clock);
        let m = st.to_matching();
        m.certify(&g).unwrap();
        assert_eq!(m.cardinality(), 2);
        assert_eq!(fixes, 0);
        assert_eq!(card, 2, "fixmatching must report the post-repair cardinality");
    }

    #[test]
    fn conflicting_paths_leave_consistent_state() {
        // Paper Fig. 1: r0 matched c1; two augmenting paths from c0 end in
        // r1 and r2; both endpoint threads run in the same warp.
        let g = from_edges(3, 2, &[(0, 0), (0, 1), (1, 1), (2, 1)]);
        let mut init = Matching::empty(3, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        let mut level = L0;
        loop {
            st.vertex_inserted = false;
            gpubfs(&g, &mut st, level, cfg(), &mut clock);
            if !st.vertex_inserted {
                break;
            }
            level += 1;
        }
        assert!(st.augmenting_path_found);
        // both r1 and r2 are endpoints
        assert_eq!(st.rmatch[1], -2);
        assert_eq!(st.rmatch[2], -2);
        alternate(&mut st, cfg(), None, &mut clock);
        fixmatching(&mut st, cfg(), &mut clock);
        let m = st.to_matching();
        m.validate(&g).unwrap();
        assert_eq!(m.cardinality(), 2, "one of the two paths must be realized");
    }

    #[test]
    fn fixmatching_clears_sentinels_and_dangles() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(3, 3));
        st.rmatch = vec![-2, 1, 2];
        st.cmatch = vec![-1, 1, 0]; // (r1,c1) consistent; c2 dangles to r0? no: cmatch[2]=0 but rmatch[0]=-2
        let (fixes, card) = fixmatching(&mut st, cfg(), &mut clock);
        assert_eq!(st.rmatch, vec![-1, 1, -1]);
        assert_eq!(st.cmatch, vec![-1, 1, -1]);
        assert_eq!(fixes, 3);
        assert_eq!(card, 1);
    }

    #[test]
    fn wr_chosen_endpoint_selection() {
        let g = from_edges(2, 1, &[(0, 0), (1, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(2, 1));
        init_bfsarray_and_run_wr(&g, &mut st, &mut clock);
        // both rows flagged -2, but only the encoded one is chosen
        let chosen = wr_chosen_endpoints(&st);
        assert_eq!(chosen.len(), 1);
        let r = chosen[0] as usize;
        assert_eq!(st.bfs_array[0], -(r as i32 + 1));
    }

    fn init_bfsarray_and_run_wr(g: &BipartiteCsr, st: &mut GpuState, clock: &mut DeviceClock) {
        init_bfs_array(st, cfg(), true, clock);
        gpubfs_wr(g, st, L0, cfg(), true, clock);
    }

    #[test]
    fn init_bfs_array_frontier_matches_plain() {
        let g = from_edges(2, 3, &[(0, 0), (1, 1), (0, 2)]);
        let mut init = Matching::empty(2, 3);
        init.join(1, 1);
        let (mut plain, mut c1) = fresh(&g, &init);
        init_bfs_array(&mut plain, cfg(), true, &mut c1);
        let (mut fc, mut c2) = fresh(&g, &init);
        let mut frontier = vec![99, 99]; // stale contents must be cleared
        init_bfs_array_frontier(&mut fc, cfg(), true, &mut frontier, &mut c2);
        assert_eq!(frontier, vec![0, 2], "initial frontier = unmatched columns in order");
        assert_eq!(fc.bfs_array, plain.bfs_array);
        assert_eq!(fc.root, plain.root);
        assert_eq!(fc.predecessor, plain.predecessor);
        assert!(c2.cycles > c1.cycles, "worklist build must cost extra");
    }

    #[test]
    fn init_bfs_array_seeded_activates_only_live_seeds() {
        // c0 and c2 unmatched, c1 matched — but only c2 is seeded, so c0
        // stays dormant (L0-1) even though it is free; matched, duplicate
        // and out-of-range seeds are skipped
        let g = from_edges(2, 3, &[(0, 0), (1, 1), (0, 2)]);
        let mut init = Matching::empty(2, 3);
        init.join(1, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        let mut frontier = vec![7u32]; // stale contents must be cleared
        init_bfs_array_seeded(
            &mut st,
            cfg(),
            true,
            &[2, 1, 2, 9],
            Some(&mut frontier),
            &mut clock,
        );
        assert_eq!(st.bfs_array, vec![L0 - 1, L0 - 1, L0]);
        assert_eq!(st.root, vec![-1, -1, 2]);
        assert!(st.predecessor.iter().all(|&p| p == -1));
        assert_eq!(frontier, vec![2]);
        // FullScan flavour: no worklist, same bfs_array
        let (mut st2, mut c2) = fresh(&g, &init);
        init_bfs_array_seeded(&mut st2, cfg(), false, &[2, 1, 2, 9], None, &mut c2);
        assert_eq!(st2.bfs_array, st.bfs_array);
        // empty seed set leaves every column dormant
        let (mut st3, mut c3) = fresh(&g, &init);
        let mut f3 = Vec::new();
        init_bfs_array_seeded(&mut st3, cfg(), false, &[], Some(&mut f3), &mut c3);
        assert!(st3.bfs_array.iter().all(|&b| b == L0 - 1));
        assert!(f3.is_empty());
    }

    #[test]
    fn gpubfs_frontier_matches_full_scan_on_race_free_graph() {
        // c0 free, r0 matched to c1, r1 free: no write races, so the two
        // modes must produce bit-identical device state level by level.
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);

        let (mut full, mut cf) = fresh(&g, &init);
        init_bfs_array(&mut full, cfg(), false, &mut cf);
        let (mut fc, mut cc) = fresh(&g, &init);
        let mut frontier: Vec<u32> = Vec::new();
        init_bfs_array_frontier(&mut fc, cfg(), false, &mut frontier, &mut cc);
        assert_eq!(frontier, vec![0]);

        let mut next: Vec<u32> = Vec::new();
        let mut endpoints: Vec<u32> = Vec::new();
        let mut level = L0;
        loop {
            full.vertex_inserted = false;
            let e_full = gpubfs(&g, &mut full, level, cfg(), &mut cf);
            fc.vertex_inserted = false;
            next.clear();
            let e_fc = gpubfs_frontier(
                &g,
                &mut fc,
                level,
                &frontier,
                &mut next,
                &mut endpoints,
                cfg(),
                &mut cc,
            );
            assert_eq!(e_full, e_fc, "level {level}: same edges scanned");
            assert_eq!(fc.bfs_array, full.bfs_array, "level {level}");
            assert_eq!(fc.predecessor, full.predecessor, "level {level}");
            assert_eq!(fc.rmatch, full.rmatch, "level {level}");
            assert_eq!(fc.vertex_inserted, full.vertex_inserted);
            assert_eq!(fc.augmenting_path_found, full.augmenting_path_found);
            if !full.vertex_inserted {
                break;
            }
            std::mem::swap(&mut frontier, &mut next);
            level += 1;
        }
        assert!(fc.augmenting_path_found);
        assert_eq!(endpoints, vec![1], "flagged row compacted into the endpoint worklist");
        // (cost wins need nc >> |frontier|; see sparse_frontier_launch_beats_
        // full_scan and the driver-level cost test — this graph is too tiny)
        assert!(cc.launches == cf.launches);
    }

    #[test]
    fn gpubfs_wr_frontier_early_exit_stops_tree() {
        // mirror of gpubfs_wr_early_exit_stops_tree through the worklist
        let g = from_edges(3, 2, &[(0, 0), (1, 0), (1, 1), (2, 1)]);
        let mut init = Matching::empty(3, 2);
        init.join(1, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        let mut frontier: Vec<u32> = Vec::new();
        init_bfs_array_frontier(&mut st, cfg(), true, &mut frontier, &mut clock);
        assert_eq!(frontier, vec![0]);
        let mut next: Vec<u32> = Vec::new();
        let mut endpoints: Vec<u32> = Vec::new();
        gpubfs_wr_frontier(
            &g,
            &mut st,
            L0,
            &frontier,
            &mut next,
            &mut endpoints,
            cfg(),
            false,
            &mut clock,
        );
        assert!(st.augmenting_path_found);
        assert_eq!(st.bfs_array[0], L0 - 2);
        assert_eq!(next, vec![1], "claimed column compacted into the next frontier");
        assert_eq!(endpoints, vec![0], "flagged row compacted into the endpoint worklist");
        assert_eq!(st.root[1], 0);
        let frontier = next;
        let mut next: Vec<u32> = Vec::new();
        let scanned = gpubfs_wr_frontier(
            &g,
            &mut st,
            L0 + 1,
            &frontier,
            &mut next,
            &mut endpoints,
            cfg(),
            false,
            &mut clock,
        );
        assert_eq!(scanned, 0, "satisfied tree must not expand");
        assert!(next.is_empty());
        assert_eq!(endpoints, vec![0]);
    }

    #[test]
    fn parallel_gpubfs_claims_same_levels_as_serial() {
        // which columns get claimed per level is schedule-independent
        // (claims are first-wins either way); only predecessor winners may
        // differ — so bfs_array and rmatch must match serial bit-for-bit.
        let g = crate::graph::gen::Family::Road.generate(900, 5);
        let init = crate::matching::init::InitHeuristic::Cheap.run(&g);
        let par = LaunchCfg { par_threads: 4, ..cfg() };
        let (mut a, mut ca) = fresh(&g, &init);
        init_bfs_array(&mut a, cfg(), false, &mut ca);
        let (mut b, mut cb) = fresh(&g, &init);
        init_bfs_array(&mut b, par, false, &mut cb);
        let mut level = L0;
        loop {
            a.vertex_inserted = false;
            let ea = gpubfs(&g, &mut a, level, cfg(), &mut ca);
            b.vertex_inserted = false;
            let eb = gpubfs(&g, &mut b, level, par, &mut cb);
            assert_eq!(ea, eb, "level {level}: same edges scanned");
            assert_eq!(a.bfs_array, b.bfs_array, "level {level}");
            assert_eq!(a.rmatch, b.rmatch, "level {level}");
            assert_eq!(a.vertex_inserted, b.vertex_inserted);
            assert_eq!(a.augmenting_path_found, b.augmenting_path_found);
            if !a.vertex_inserted {
                break;
            }
            level += 1;
        }
        assert!(cb.cycles >= ca.cycles, "the atomic path pays the CAS charges");
    }

    #[test]
    fn parallel_frontier_gpubfs_matches_serial_claim_sets() {
        let g = crate::graph::gen::Family::Banded.generate(700, 9);
        let init = crate::matching::init::InitHeuristic::Cheap.run(&g);
        let par = LaunchCfg { par_threads: 4, ..cfg() };
        let (mut a, mut ca) = fresh(&g, &init);
        let mut fa: Vec<u32> = Vec::new();
        init_bfs_array_frontier(&mut a, cfg(), false, &mut fa, &mut ca);
        let (mut b, mut cb) = fresh(&g, &init);
        let mut fb: Vec<u32> = Vec::new();
        init_bfs_array_frontier(&mut b, par, false, &mut fb, &mut cb);
        assert_eq!(fa, fb);
        let (mut na, mut ea_pts) = (Vec::new(), Vec::new());
        let (mut nb, mut eb_pts) = (Vec::new(), Vec::new());
        let mut level = L0;
        loop {
            a.vertex_inserted = false;
            na.clear();
            gpubfs_frontier(&g, &mut a, level, &fa, &mut na, &mut ea_pts, cfg(), &mut ca);
            b.vertex_inserted = false;
            nb.clear();
            gpubfs_frontier(&g, &mut b, level, &fb, &mut nb, &mut eb_pts, par, &mut cb);
            assert_eq!(a.bfs_array, b.bfs_array, "level {level}");
            assert_eq!(a.rmatch, b.rmatch, "level {level}");
            // worklists may be permuted by the racy claim winners; the
            // *sets* must agree
            let (mut sa, mut sb) = (na.clone(), nb.clone());
            sa.sort_unstable();
            sb.sort_unstable();
            assert_eq!(sa, sb, "level {level}: same claimed columns");
            if !a.vertex_inserted {
                break;
            }
            std::mem::swap(&mut fa, &mut na);
            std::mem::swap(&mut fb, &mut nb);
            level += 1;
        }
        let (mut sa, mut sb) = (ea_pts.clone(), eb_pts.clone());
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb, "same endpoint rows flagged");
    }

    #[test]
    fn parallel_alternate_realizes_paths_and_repairs() {
        // c0 - r0 = c1 - r1 through the atomic (swap-based) ALTERNATE
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);
        let par = LaunchCfg { par_threads: 4, ..cfg() };
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, par, false, &mut clock);
        gpubfs(&g, &mut st, L0, par, &mut clock);
        gpubfs(&g, &mut st, L0 + 1, par, &mut clock);
        alternate(&mut st, par, None, &mut clock);
        let (_, card) = fixmatching(&mut st, par, &mut clock);
        let m = st.to_matching();
        m.certify(&g).unwrap();
        assert_eq!(m.cardinality(), 2);
        assert_eq!(card, 2);
    }

    #[test]
    fn wr_chosen_endpoints_from_matches_full_scan() {
        let g = from_edges(2, 1, &[(0, 0), (1, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(2, 1));
        init_bfsarray_and_run_wr(&g, &mut st, &mut clock);
        let scan = wr_chosen_endpoints(&st);
        let all_rows: Vec<u32> = (0..2).collect();
        assert_eq!(wr_chosen_endpoints_from(&st, &all_rows), scan);
        assert!(wr_chosen_endpoints_from(&st, &[]).is_empty());
    }

    #[test]
    fn parallel_init_and_fix_match_serial() {
        let g = from_edges(4, 4, &[(0, 0), (1, 0), (1, 1), (2, 2), (3, 3), (0, 3)]);
        let mut init = Matching::empty(4, 4);
        init.join(1, 1);
        init.join(2, 2);
        let par = LaunchCfg { par_threads: 4, ..cfg() };

        let (mut a, mut ca) = fresh(&g, &init);
        init_bfs_array(&mut a, cfg(), true, &mut ca);
        let (mut b, mut cb) = fresh(&g, &init);
        init_bfs_array(&mut b, par, true, &mut cb);
        assert_eq!(a.bfs_array, b.bfs_array);
        assert_eq!(a.root, b.root);
        assert_eq!(a.predecessor, b.predecessor);
        assert_eq!(ca.cycles, cb.cycles, "modeled cycles must not depend on host threads");

        // seed both with the same inconsistent speculative state
        for st in [&mut a, &mut b] {
            st.rmatch = vec![-2, 1, 2, -1];
            st.cmatch = vec![-1, 1, 0, 3];
        }
        let (fx_a, card_a) = fixmatching(&mut a, cfg(), &mut ca);
        let (fx_b, card_b) = fixmatching(&mut b, par, &mut cb);
        assert_eq!(a.rmatch, b.rmatch);
        assert_eq!(a.cmatch, b.cmatch);
        assert_eq!((fx_a, card_a), (fx_b, card_b));
        assert_eq!(ca.cycles, cb.cycles);
    }
}
