//! The paper's device kernels (Algorithms 2–4 plus the init and fix
//! kernels), executed on the [`super::device`] model.
//!
//! All array/sentinel conventions match the paper exactly:
//! * `rmatch[r] = -1` unmatched, `-2` = endpoint of a discovered
//!   augmenting path (set by the BFS kernels, consumed by ALTERNATE).
//! * `bfs_array[c] = L0-1` for matched (unvisited) columns, `L0` for
//!   unmatched columns (BFS start level), `level+1` when claimed.
//! * GPUBFS-WR: `bfs_array[root] < L0-1` marks a satisfied root. With
//!   `L0 = 2`, live levels are positive, so the APsB improvement encodes
//!   the chosen endpoint row as a non-positive value. (We store
//!   `-(row+1)`, not the paper's `-(row)`: row 0 would collide with the
//!   plain "satisfied" marker `L0-2 = 0` — an off-by-one latent in the
//!   paper's description.)

use super::config::{ThreadMapping, WriteOrder};
use super::device::{launch, DeviceClock, StepPlan, WarpStepper};
use crate::graph::csr::BipartiteCsr;
use crate::matching::Matching;

/// BFS start level. The paper's APsB-GPUBFS-WR improvement requires
/// `L0 = 2` so that `bfs_array` stays positive for live levels.
pub const L0: i32 = 2;

/// Device-resident state for one matching computation.
#[derive(Debug, Clone)]
pub struct GpuState {
    pub bfs_array: Vec<i32>,
    pub predecessor: Vec<i32>,
    pub root: Vec<i32>,
    pub rmatch: Vec<i32>,
    pub cmatch: Vec<i32>,
    pub vertex_inserted: bool,
    pub augmenting_path_found: bool,
}

impl GpuState {
    pub fn new(g: &BipartiteCsr, init: &Matching) -> Self {
        Self {
            bfs_array: vec![0; g.nc],
            predecessor: vec![-1; g.nr],
            root: vec![-1; g.nc],
            rmatch: init.rmatch.clone(),
            cmatch: init.cmatch.clone(),
            vertex_inserted: false,
            augmenting_path_found: false,
        }
    }

    pub fn cardinality(&self) -> usize {
        self.cmatch.iter().filter(|&&r| r >= 0).count()
    }

    /// Extract a host [`Matching`] (must be called only after FIXMATCHING;
    /// sentinels would fail validation).
    pub fn to_matching(&self) -> Matching {
        Matching { rmatch: self.rmatch.clone(), cmatch: self.cmatch.clone() }
    }
}

/// Kernel launch parameters shared by every kernel in one run.
#[derive(Debug, Clone, Copy)]
pub struct LaunchCfg {
    pub mapping: ThreadMapping,
    pub order: WriteOrder,
    pub seed: u64,
}

/// INITBFSARRAY (§3): `bfs_array[c] = L0-1` if matched else `L0`; also
/// resets per-phase arrays (predecessor; root when `with_root`).
pub fn init_bfs_array(state: &mut GpuState, cfg: LaunchCfg, with_root: bool, clock: &mut DeviceClock) {
    let nc = state.cmatch.len();
    let cmatch = &state.cmatch;
    let bfs_array = &mut state.bfs_array;
    let root = &mut state.root;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
        if cmatch[c] > -1 {
            bfs_array[c] = L0 - 1;
            if with_root {
                root[c] = -1;
            }
        } else {
            bfs_array[c] = L0;
            if with_root {
                root[c] = c as i32;
            }
        }
        0
    });
    let nr = state.predecessor.len();
    let predecessor = &mut state.predecessor;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
        predecessor[r] = -1;
        0
    });
}

/// GPUBFS — Algorithm 2: one level expansion over all columns.
pub fn gpubfs(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    clock: &mut DeviceClock,
) -> u64 {
    let mut edges_total = 0u64;
    let GpuState { bfs_array, predecessor, rmatch, vertex_inserted, augmenting_path_found, .. } =
        state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, g.nc, |col_vertex| {
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    predecessor[neighbor_row] = col_vertex as i32;
                }
            } else if col_match == -1 {
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// GPUBFS-WR — Algorithm 4: level expansion carrying the `root` array,
/// with early exit for satisfied roots. `encode_endpoint` enables the
/// APsB improvement (store the chosen endpoint row in the root's
/// `bfs_array` slot).
pub fn gpubfs_wr(
    g: &BipartiteCsr,
    state: &mut GpuState,
    bfs_level: i32,
    cfg: LaunchCfg,
    encode_endpoint: bool,
    clock: &mut DeviceClock,
) -> u64 {
    let mut edges_total = 0u64;
    let GpuState {
        bfs_array,
        predecessor,
        root,
        rmatch,
        vertex_inserted,
        augmenting_path_found,
        ..
    } = state;
    launch(clock, cfg.mapping, cfg.order, cfg.seed, g.nc, |col_vertex| {
        if bfs_array[col_vertex] != bfs_level {
            return 0;
        }
        let my_root = root[col_vertex];
        debug_assert!(my_root >= 0, "root must be set before a column joins the frontier");
        if bfs_array[my_root as usize] < L0 - 1 {
            return 0; // early exit: this tree already found a path
        }
        let mut edges = 0u64;
        for &nr in g.col_neighbors(col_vertex) {
            edges += 1;
            let neighbor_row = nr as usize;
            let col_match = rmatch[neighbor_row];
            if col_match > -1 {
                if bfs_array[col_match as usize] == L0 - 1 {
                    *vertex_inserted = true;
                    bfs_array[col_match as usize] = bfs_level + 1;
                    root[col_match as usize] = my_root;
                    predecessor[neighbor_row] = col_vertex as i32;
                }
            } else if col_match == -1 {
                bfs_array[my_root as usize] = if encode_endpoint {
                    -(neighbor_row as i32 + 1)
                } else {
                    L0 - 2
                };
                rmatch[neighbor_row] = -2;
                predecessor[neighbor_row] = col_vertex as i32;
                *augmenting_path_found = true;
            }
        }
        edges_total += edges;
        edges
    });
    edges_total
}

/// ALTERNATE — Algorithm 3, executed in intra-warp lockstep so the
/// paper's same-warp double-claim inconsistency actually occurs (and is
/// then repaired by FIXMATCHING). `only_rows` restricts the starting rows
/// (used by the WR variant); `None` starts from every `rmatch == -2` row.
pub fn alternate(
    state: &mut GpuState,
    cfg: LaunchCfg,
    only_rows: Option<Vec<u32>>,
    clock: &mut DeviceClock,
) {
    // thread payload: (current row_vertex, steps taken)
    let max_steps = (state.rmatch.len() + state.cmatch.len() + 2) as u32;
    let mut threads: Vec<(i32, u32)> = match only_rows {
        Some(rows) => rows.into_iter().map(|r| (r as i32, 0)).collect(),
        None => (0..state.rmatch.len())
            .filter(|&r| state.rmatch[r] == -2)
            .map(|r| (r as i32, 0))
            .collect(),
    };
    let stepper = WarpStepper { order: cfg.order, seed: cfg.seed };
    /// the memory the ALTERNATE kernel touches
    struct Mem<'a> {
        predecessor: &'a [i32],
        rmatch: &'a mut [i32],
        cmatch: &'a mut [i32],
    }
    let mut mem = Mem {
        predecessor: &state.predecessor,
        rmatch: &mut state.rmatch,
        cmatch: &mut state.cmatch,
    };
    stepper.run(
        clock,
        &mut threads,
        &mut mem,
        // read phase (one lockstep iteration of the while loop, lines 5–9)
        |mem, &(row_vertex, steps)| {
            if row_vertex < 0 || steps >= max_steps {
                return StepPlan::Done;
            }
            let matched_col = mem.predecessor[row_vertex as usize];
            if matched_col < 0 {
                return StepPlan::Done; // stale/cleared predecessor guard
            }
            let matched_row = mem.cmatch[matched_col as usize];
            // paper line 8: another alternation already claimed this column
            if matched_row > -1 && mem.predecessor[matched_row as usize] == matched_col {
                return StepPlan::Done;
            }
            StepPlan::Write((matched_col, matched_row))
        },
        // write phase (lines 10–12), applied in lane order
        |mem, t, (matched_col, matched_row)| {
            let (row_vertex, steps) = *t;
            mem.cmatch[matched_col as usize] = row_vertex;
            mem.rmatch[row_vertex as usize] = matched_col;
            *t = (matched_row, steps + 1);
            matched_row != -1
        },
    );
}

/// Starting rows for the APsB-GPUBFS-WR improved ALTERNATE: only the row
/// encoded in its root's `bfs_array` slot alternates; every other
/// `rmatch == -2` row is left for FIXMATCHING to reset.
pub fn wr_chosen_endpoints(state: &GpuState) -> Vec<u32> {
    (0..state.rmatch.len())
        .filter(|&r| {
            if state.rmatch[r] != -2 {
                return false;
            }
            let c = state.predecessor[r];
            if c < 0 {
                return false;
            }
            let rt = state.root[c as usize];
            if rt < 0 {
                return false;
            }
            state.bfs_array[rt as usize] == -(r as i32 + 1)
        })
        .map(|r| r as u32)
        .collect()
}

/// FIXMATCHING (§3): clear leftover `-2` sentinels and dangling pointers,
/// keeping exactly the mutually-consistent pairs. Two passes: rows against
/// cmatch, then columns against the repaired rmatch. Returns #resets.
pub fn fixmatching(state: &mut GpuState, cfg: LaunchCfg, clock: &mut DeviceClock) -> u64 {
    let mut fixes = 0u64;
    {
        let GpuState { rmatch, cmatch, .. } = state;
        let nr = rmatch.len();
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nr, |r| {
            let c = rmatch[r];
            if c == -2 || (c >= 0 && cmatch[c as usize] != r as i32) {
                rmatch[r] = -1;
                fixes += 1;
            }
            0
        });
    }
    {
        let GpuState { rmatch, cmatch, .. } = state;
        let nc = cmatch.len();
        launch(clock, cfg.mapping, cfg.order, cfg.seed, nc, |c| {
            let r = cmatch[c];
            if r >= 0 && rmatch[r as usize] != c as i32 {
                cmatch[c] = -1;
                fixes += 1;
            }
            0
        });
    }
    fixes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::gpu::config::{ThreadMapping, WriteOrder};

    fn cfg() -> LaunchCfg {
        LaunchCfg { mapping: ThreadMapping::Mt, order: WriteOrder::Forward, seed: 0 }
    }

    fn fresh(g: &BipartiteCsr, init: &Matching) -> (GpuState, DeviceClock) {
        (GpuState::new(g, init), DeviceClock::default())
    }

    #[test]
    fn init_bfs_array_levels() {
        let g = from_edges(2, 3, &[(0, 0), (1, 1), (0, 2)]);
        let mut init = Matching::empty(2, 3);
        init.join(1, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), true, &mut clock);
        assert_eq!(st.bfs_array, vec![L0, L0 - 1, L0]);
        assert_eq!(st.root, vec![0, -1, 2]);
        assert!(st.predecessor.iter().all(|&p| p == -1));
    }

    #[test]
    fn gpubfs_finds_direct_augmenting_path() {
        // unmatched c0 adjacent to free r0
        let g = from_edges(1, 1, &[(0, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(1, 1));
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.rmatch[0], -2);
        assert_eq!(st.predecessor[0], 0);
    }

    #[test]
    fn gpubfs_expands_through_matched_rows() {
        // c0 free, r0 matched to c1, r1 free: c0-r0 forces c1 into level 3
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        assert!(!st.augmenting_path_found);
        assert!(st.vertex_inserted);
        assert_eq!(st.bfs_array[1], L0 + 1);
        st.vertex_inserted = false;
        gpubfs(&g, &mut st, L0 + 1, cfg(), &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.rmatch[1], -2);
        assert_eq!(st.predecessor[1], 1);
    }

    #[test]
    fn gpubfs_wr_early_exit_stops_tree() {
        // two columns in the same tree; after the root is satisfied the
        // other column must not expand.
        let g = from_edges(3, 2, &[(0, 0), (1, 0), (1, 1), (2, 1)]);
        let mut init = Matching::empty(3, 2);
        init.join(1, 1); // c1 matched to r1
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), true, &mut clock);
        // level L0: c0 frontier; finds free r0 -> root satisfied
        gpubfs_wr(&g, &mut st, L0, cfg(), false, &mut clock);
        assert!(st.augmenting_path_found);
        assert_eq!(st.bfs_array[0], L0 - 2);
        // c1 was claimed into the frontier at L0+1 under root 0
        assert_eq!(st.root[1], 0);
        let scanned = gpubfs_wr(&g, &mut st, L0 + 1, cfg(), false, &mut clock);
        assert_eq!(scanned, 0, "satisfied tree must not expand");
    }

    #[test]
    fn alternate_realizes_simple_path() {
        let g = from_edges(1, 1, &[(0, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(1, 1));
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        alternate(&mut st, cfg(), None, &mut clock);
        fixmatching(&mut st, cfg(), &mut clock);
        assert_eq!(st.rmatch, vec![0]);
        assert_eq!(st.cmatch, vec![0]);
        st.to_matching().certify(&g).unwrap();
    }

    #[test]
    fn alternate_flips_length3_path() {
        // c0 - r0 = c1 - r1 (c0 free, r1 free; r0 matched to c1)
        let g = from_edges(2, 2, &[(0, 0), (0, 1), (1, 1)]);
        let mut init = Matching::empty(2, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        gpubfs(&g, &mut st, L0, cfg(), &mut clock);
        gpubfs(&g, &mut st, L0 + 1, cfg(), &mut clock);
        alternate(&mut st, cfg(), None, &mut clock);
        let fixes = fixmatching(&mut st, cfg(), &mut clock);
        let m = st.to_matching();
        m.certify(&g).unwrap();
        assert_eq!(m.cardinality(), 2);
        assert_eq!(fixes, 0);
    }

    #[test]
    fn conflicting_paths_leave_consistent_state() {
        // Paper Fig. 1: r0 matched c1; two augmenting paths from c0 end in
        // r1 and r2; both endpoint threads run in the same warp.
        let g = from_edges(3, 2, &[(0, 0), (0, 1), (1, 1), (2, 1)]);
        let mut init = Matching::empty(3, 2);
        init.join(0, 1);
        let (mut st, mut clock) = fresh(&g, &init);
        init_bfs_array(&mut st, cfg(), false, &mut clock);
        let mut level = L0;
        loop {
            st.vertex_inserted = false;
            gpubfs(&g, &mut st, level, cfg(), &mut clock);
            if !st.vertex_inserted {
                break;
            }
            level += 1;
        }
        assert!(st.augmenting_path_found);
        // both r1 and r2 are endpoints
        assert_eq!(st.rmatch[1], -2);
        assert_eq!(st.rmatch[2], -2);
        alternate(&mut st, cfg(), None, &mut clock);
        fixmatching(&mut st, cfg(), &mut clock);
        let m = st.to_matching();
        m.validate(&g).unwrap();
        assert_eq!(m.cardinality(), 2, "one of the two paths must be realized");
    }

    #[test]
    fn fixmatching_clears_sentinels_and_dangles() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(3, 3));
        st.rmatch = vec![-2, 1, 2];
        st.cmatch = vec![-1, 1, 0]; // (r1,c1) consistent; c2 dangles to r0? no: cmatch[2]=0 but rmatch[0]=-2
        let fixes = fixmatching(&mut st, cfg(), &mut clock);
        assert_eq!(st.rmatch, vec![-1, 1, -1]);
        assert_eq!(st.cmatch, vec![-1, 1, -1]);
        assert_eq!(fixes, 3);
    }

    #[test]
    fn wr_chosen_endpoint_selection() {
        let g = from_edges(2, 1, &[(0, 0), (1, 0)]);
        let (mut st, mut clock) = fresh(&g, &Matching::empty(2, 1));
        init_bfsarray_and_run_wr(&g, &mut st, &mut clock);
        // both rows flagged -2, but only the encoded one is chosen
        let chosen = wr_chosen_endpoints(&st);
        assert_eq!(chosen.len(), 1);
        let r = chosen[0] as usize;
        assert_eq!(st.bfs_array[0], -(r as i32 + 1));
    }

    fn init_bfsarray_and_run_wr(g: &BipartiteCsr, st: &mut GpuState, clock: &mut DeviceClock) {
        init_bfs_array(st, cfg(), true, clock);
        gpubfs_wr(g, st, L0, cfg(), true, clock);
    }
}
