//! Crash recovery: snapshot + WAL-tail replay + seeded repair.
//!
//! For each graph name found in the data dir:
//!
//! 1. the newest snapshot that passes its checksum anchors the state —
//!    graph, structural version, and (usually) the maintained maximum
//!    matching;
//! 2. the WAL tail is replayed through [`DynamicGraph::apply`]: only
//!    update frames from the snapshot's incarnation (`version >> 32`)
//!    and newer than its version run, so replay is idempotent w.r.t. the
//!    snapshot and immune to stale frames from a previous `LOAD` of the
//!    same name; each frame's re-applied [`ApplyReport`] is cross-checked
//!    against the logged one, and any mismatch (or a torn tail, or a
//!    version gap) ends the replay at the last consistent prefix;
//! 3. the replayed reports are folded into one *net* report
//!    ([`ApplyReport::absorb`]) and the snapshot matching is patched
//!    forward by [`crate::dynamic::repair`] — the augmenting search seeds
//!    from exactly the columns the replayed deltas exposed, so recovery
//!    costs `O(|replayed deltas| + reached subgraph)`, not a from-scratch
//!    solve.
//!
//! A graph whose WAL ends in a DROP marker of its own incarnation
//! recovers as *dropped* (the interrupted deletion is completed); a name
//! with no usable snapshot is unrecoverable and reported as skipped.

use super::{snapshot, wal, Persistence};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router;
use crate::coordinator::store::{CachedMatching, GraphStore};
use crate::dynamic::{self, ApplyReport, DeltaBatch, DynamicGraph};
use crate::matching::algo::{RunCtx, RunOutcome};
use crate::matching::Matching;
use crate::runtime::Engine;
use crate::util::pool::WorkspacePool;
use std::io;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// One graph reconstructed from disk, before repair/installation.
pub struct RecoveredGraph {
    pub name: String,
    /// live graph: snapshot base + replayed WAL tail, version restored
    pub graph: DynamicGraph,
    /// the snapshot's cached matching (valid for the snapshot version;
    /// [`install_recovered`] patches it forward through `repair`)
    pub matching: Option<Matching>,
    pub snapshot_version: u64,
    /// net effect of the replayed tail relative to the snapshot
    pub net_report: ApplyReport,
    pub replayed_updates: usize,
    /// false when a torn/corrupt/mismatched tail was dropped — the state
    /// is still a consistent prefix, just not the full log
    pub clean: bool,
}

/// What recovering one name did (the observable half of
/// [`RecoveredGraph`], kept by the service for tests and operators).
#[derive(Debug, Clone)]
pub struct GraphRecovery {
    pub name: String,
    /// structural version the graph recovered at
    pub version: u64,
    pub replayed_updates: usize,
    /// cardinality of the repaired matching (None: recovered matchingless)
    pub cardinality: Option<usize>,
    /// phases the seeded repair run took (None: no matching to repair) —
    /// the e2e durability proof asserts this undercuts a cold recompute
    pub repair_phases: Option<u64>,
    /// columns the repair seeded from
    pub seeds: usize,
    pub clean: bool,
}

/// Startup recovery summary.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    pub graphs: Vec<GraphRecovery>,
    /// names with on-disk state that could not be recovered (no valid
    /// snapshot to anchor a replay)
    pub skipped: Vec<String>,
}

impl RecoveryReport {
    pub fn recovered(&self) -> usize {
        self.graphs.len()
    }
}

/// Disposition of one logged update frame against a live graph.
pub enum FrameStep {
    /// applied cleanly; carries the report the re-apply reproduced
    Applied(ApplyReport),
    /// stale — an older incarnation, or at/below the floor version the
    /// anchor state already covers; replay is idempotent past it
    Skipped,
    /// version gap, unparseable wire, or a report mismatch: the caller
    /// stops at the consistent prefix (recovery) or resyncs from a fresh
    /// baseline (replication)
    Halt,
}

/// Replay one WAL `Update` frame onto `dg`. This is *the* replay kernel:
/// crash recovery and the replication follower both run every frame
/// through it, so the incarnation scoping, gap detection, and
/// report cross-check are byte-for-byte the same on both paths. The
/// frame is applied on a scratch copy first — a mismatch leaves `dg`
/// untouched.
pub fn apply_update_frame(
    dg: &mut DynamicGraph,
    incarnation: u64,
    floor_version: u64,
    version_after: u64,
    batch_wire: &str,
    report_wire: &str,
) -> FrameStep {
    if version_after >> 32 != incarnation || version_after <= floor_version {
        return FrameStep::Skipped; // older incarnation, or already covered
    }
    if version_after != dg.version() + 1 {
        return FrameStep::Halt; // gap
    }
    let parsed = DeltaBatch::parse_wire(batch_wire)
        .and_then(|b| ApplyReport::parse_wire(report_wire).map(|r| (b, r)));
    let Ok((batch, want)) = parsed else {
        return FrameStep::Halt;
    };
    let mut next = dg.clone();
    let got = next.apply(&batch);
    let matches = got.inserted == want.inserted
        && got.deleted == want.deleted
        && got.added_cols == want.added_cols
        && got.added_rows == want.added_rows
        && next.version() == version_after;
    if !matches {
        return FrameStep::Halt;
    }
    *dg = next;
    FrameStep::Applied(got)
}

/// Snapshot + replay for one name. Callers hold the per-name lock (use
/// [`Persistence::recover_graph`]).
pub(super) fn recover_graph(
    p: &Persistence,
    name: &str,
) -> io::Result<Option<RecoveredGraph>> {
    // anchor candidates come in two on-disk layouts — single-file
    // snapshots and per-shard sets — merged newest-version-first so a
    // sharded store's newest state wins over an older combined file (and
    // vice versa). An unassemblable set (missing/corrupt member) is
    // skipped the same way a corrupt .snap is.
    let mut snap = None;
    let combined = p.snapshots_of(name);
    let sharded = p.shard_snapshot_sets(name);
    let (mut ci, mut si) = (0usize, 0usize);
    while snap.is_none() && (ci < combined.len() || si < sharded.len()) {
        let take_combined = match (combined.get(ci), sharded.get(si)) {
            (Some((cv, _)), Some((sv, _))) => cv >= sv,
            (Some(_), None) => true,
            _ => false,
        };
        if take_combined {
            snap = snapshot::read_snapshot(&combined[ci].1)?;
            ci += 1;
        } else {
            snap = p.read_shard_set(&sharded[si].1)?;
            si += 1;
        }
    }
    let (records, torn) = wal::read_wal(&p.wal_path(name))?;
    let Some(snap) = snap else {
        return Ok(None); // no anchor: WAL alone cannot rebuild a graph
    };
    let incarnation = snap.version >> 32;
    let snapshot_version = snap.version;
    let mut dg =
        DynamicGraph::from_arc(Arc::new(snap.graph)).with_version_base(snapshot_version);
    let mut net = ApplyReport::default();
    let mut replayed = 0usize;
    let mut clean = !torn;
    let mut dropped = false;
    for rec in records {
        match rec {
            // the graph itself lives in the snapshot; the marker only
            // documents the reset
            wal::WalRecord::Load { .. } => {}
            wal::WalRecord::Drop { version } => {
                if version >> 32 == incarnation {
                    dropped = true;
                }
            }
            wal::WalRecord::Update { version_after, batch_wire, report_wire } => {
                match apply_update_frame(
                    &mut dg,
                    incarnation,
                    snapshot_version,
                    version_after,
                    &batch_wire,
                    &report_wire,
                ) {
                    FrameStep::Applied(got) => {
                        net.absorb(&got);
                        replayed += 1;
                    }
                    FrameStep::Skipped => {}
                    FrameStep::Halt => {
                        clean = false; // stop at the consistent prefix
                        break;
                    }
                }
            }
        }
    }
    if dropped {
        // complete the interrupted DROP: the marker is authoritative
        p.delete_graph_files_locked(name);
        return Ok(None);
    }
    Ok(Some(RecoveredGraph {
        name: name.to_string(),
        graph: dg,
        matching: snap.matching,
        snapshot_version,
        net_report: net,
        replayed_updates: replayed,
        clean,
    }))
}

/// Install a recovered graph into the store, restoring its matching via
/// seeded repair (router-picked spec; a GPU pick feeds the exposed
/// columns straight into the compacted-frontier BFS). Repair is
/// best-effort: if it cannot complete *and certify*, the graph is
/// installed matchingless and the next `MATCH` runs cold — recovery
/// never serves an untrusted matching.
pub fn install_recovered(
    rec: RecoveredGraph,
    store: &GraphStore,
    metrics: &Metrics,
    engine: Option<Arc<Engine>>,
    pool: &Arc<WorkspacePool>,
) -> GraphRecovery {
    let mut dg = rec.graph;
    let version = dg.version();
    let live = dg.snapshot();
    let mut cached = None;
    let mut repair_phases = None;
    let mut seeds = 0usize;
    let mut cardinality = None;
    if let Some(prev) = rec.matching {
        let spec = router::route_graph(&live);
        let mut ctx = RunCtx::new(pool.clone());
        if let Ok(summary) =
            dynamic::repair(&live, prev, &rec.net_report, &spec, engine, &mut ctx)
        {
            if summary.result.outcome == RunOutcome::Complete
                && summary.result.matching.certify(&live).is_ok()
            {
                repair_phases = Some(summary.result.stats.phases);
                seeds = summary.seeds;
                cardinality = Some(summary.result.matching.cardinality());
                cached =
                    Some(CachedMatching { matching: summary.result.matching, version });
            }
        }
    }
    store.install(&rec.name, dg, cached);
    metrics.graphs_recovered.fetch_add(1, Ordering::Relaxed);
    GraphRecovery {
        name: rec.name,
        version,
        replayed_updates: rec.replayed_updates,
        cardinality,
        repair_phases,
        seeds,
        clean: rec.clean,
    }
}

/// Startup recovery: scan the data dir and install every recoverable
/// graph. Run before the service accepts traffic.
pub fn recover_into(
    p: &Persistence,
    store: &GraphStore,
    metrics: &Metrics,
    engine: Option<Arc<Engine>>,
    pool: &Arc<WorkspacePool>,
) -> io::Result<RecoveryReport> {
    let mut report = RecoveryReport::default();
    for name in p.graph_names()? {
        match p.recover_graph(&name)? {
            Some(rec) => {
                report.graphs.push(install_recovered(
                    rec,
                    store,
                    metrics,
                    engine.clone(),
                    pool,
                ));
            }
            None => {
                // either a completed/completable DROP (files now gone) or
                // an unanchored WAL; only the latter is worth surfacing
                if p.wal_path(&name).exists()
                    || !p.snapshots_of(&name).is_empty()
                    || !p.shard_snapshot_sets(&name).is_empty()
                {
                    report.skipped.push(name);
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;

    fn persistence(tag: &str) -> (Persistence, std::path::PathBuf) {
        let dir = super::super::tests::tempdir(tag);
        (Persistence::open(&dir).unwrap(), dir)
    }

    #[test]
    fn load_then_updates_replay_to_the_live_graph() {
        let (p, dir) = persistence("replay");
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        let base = 5u64 << 32;
        p.record_load("g", &g, base).unwrap();
        // two committed updates, logged the way the executor logs them
        let mut dg = DynamicGraph::new(g).with_version_base(base);
        for batch in [
            DeltaBatch::new().insert(0, 1).delete(2, 2),
            DeltaBatch::new().add_column(vec![2]),
        ] {
            let rep = dg.apply(&batch);
            p.append_update("g", dg.version(), &rep).unwrap();
        }
        let rec = p.recover_graph("g").unwrap().expect("recoverable");
        let mut got = rec.graph;
        assert_eq!(got.version(), dg.version());
        assert_eq!(got.snapshot().edges(), dg.snapshot().edges());
        assert_eq!(rec.replayed_updates, 2);
        assert!(rec.clean);
        assert_eq!(rec.snapshot_version, base);
        // net report spans both batches
        assert_eq!(rec.net_report.added_cols, vec![3]);
        assert_eq!(rec.net_report.deleted, vec![(2, 2)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_recovers_the_prefix() {
        let (p, dir) = persistence("torn");
        let g = from_edges(2, 2, &[(0, 0), (1, 1)]);
        p.record_load("g", &g, 0).unwrap();
        let mut dg = DynamicGraph::new(g).with_version_base(0);
        let rep = dg.apply(&DeltaBatch::new().insert(0, 1));
        p.append_update("g", dg.version(), &rep).unwrap();
        let rep = dg.apply(&DeltaBatch::new().insert(1, 0));
        p.append_update("g", dg.version(), &rep).unwrap();
        // tear the final frame in half
        let wal_path = p.wal_path("g");
        let bytes = std::fs::read(&wal_path).unwrap();
        std::fs::write(&wal_path, &bytes[..bytes.len() - 5]).unwrap();
        let rec = p.recover_graph("g").unwrap().unwrap();
        assert_eq!(rec.replayed_updates, 1, "only the intact frame replays");
        assert!(!rec.clean);
        assert_eq!(rec.graph.version(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_compaction_skips_covered_frames() {
        let (p, dir) = persistence("compact");
        let g = from_edges(2, 2, &[(0, 0)]);
        p.record_load("g", &g, 0).unwrap();
        let mut dg = DynamicGraph::new(g).with_version_base(0);
        let rep = dg.apply(&DeltaBatch::new().insert(1, 1));
        p.append_update("g", dg.version(), &rep).unwrap();
        // compaction: snapshot at the live version truncates the log
        p.record_snapshot("g", &dg.snapshot(), dg.version(), None).unwrap();
        let (records, _) = wal::read_wal(&p.wal_path("g")).unwrap();
        assert!(records.is_empty(), "compaction must truncate the WAL");
        // one more update after compaction
        let rep = dg.apply(&DeltaBatch::new().insert(0, 1));
        p.append_update("g", dg.version(), &rep).unwrap();
        let rec = p.recover_graph("g").unwrap().unwrap();
        assert_eq!(rec.snapshot_version, 1);
        assert_eq!(rec.replayed_updates, 1, "only the post-snapshot frame replays");
        let mut got = rec.graph;
        assert_eq!(got.snapshot().edges(), dg.snapshot().edges());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_marker_completes_the_deletion() {
        let (p, dir) = persistence("drop");
        let g = from_edges(2, 2, &[(0, 0)]);
        p.record_load("g", &g, 0).unwrap();
        // simulate the crash window: marker written, files not yet deleted
        wal::append(&p.wal_path("g"), &wal::WalRecord::Drop { version: 0 }).unwrap();
        assert!(p.recover_graph("g").unwrap().is_none());
        assert!(!p.wal_path("g").exists(), "recovery completes the deletion");
        assert!(p.snapshots_of("g").is_empty());
        // a clean record_drop leaves nothing behind either
        p.record_load("h", &g, 1 << 32).unwrap();
        assert!(p.record_drop("h", Some(1 << 32)).unwrap());
        assert!(p.recover_graph("h").unwrap().is_none());
        assert!(!p.record_drop("h", None).unwrap(), "double drop: nothing on disk");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_frames_from_an_older_incarnation_are_ignored() {
        // crash between a re-LOAD's snapshot write and its WAL reset: the
        // new snapshot coexists with the old incarnation's WAL
        let (p, dir) = persistence("stale");
        let g0 = from_edges(2, 2, &[(0, 0)]);
        p.record_load("g", &g0, 0).unwrap();
        let mut dg = DynamicGraph::new(g0).with_version_base(0);
        let rep = dg.apply(&DeltaBatch::new().insert(1, 1));
        p.append_update("g", dg.version(), &rep).unwrap();
        // new incarnation's snapshot lands (higher version base), but the
        // WAL was not reset before the "crash"
        let g1 = from_edges(2, 2, &[(0, 1)]);
        snapshot::write_snapshot(&p.snap_path("g", 7 << 32), 7 << 32, &g1, None).unwrap();
        let rec = p.recover_graph("g").unwrap().unwrap();
        assert_eq!(rec.snapshot_version, 7 << 32);
        assert_eq!(rec.replayed_updates, 0, "old incarnation's frames must not replay");
        let mut got = rec.graph;
        assert_eq!(got.snapshot().edges(), vec![(0, 1)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_snapshots_roundtrip_load_update_recover() {
        // LOAD + UPDATEs under the per-shard layout: the shard set (one
        // WAL, K member files) must anchor replay exactly like a
        // single-file snapshot would
        let (p, dir) = persistence("shardset");
        p.set_snapshot_shards(4);
        let g = crate::graph::gen::Family::Kron.generate(300, 5);
        let base = 3u64 << 32;
        p.record_load("g", &g, base).unwrap();
        assert!(p.snapshots_of("g").is_empty(), "no single-file snapshot in sharded mode");
        let sets = p.shard_snapshot_sets("g");
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].0, base);
        assert_eq!(sets[0].1.len(), 4, "one member per shard");
        let mut dg = DynamicGraph::new(g).with_version_base(base);
        for batch in [
            DeltaBatch::new().insert(0, 1),
            DeltaBatch::new().add_column(vec![2]),
        ] {
            let rep = dg.apply(&batch);
            p.append_update("g", dg.version(), &rep).unwrap();
        }
        let rec = p.recover_graph("g").unwrap().expect("shard set anchors");
        assert_eq!(rec.snapshot_version, base);
        assert_eq!(rec.replayed_updates, 2);
        assert!(rec.clean);
        let mut got = rec.graph;
        assert_eq!(got.version(), dg.version());
        assert_eq!(got.snapshot().edges(), dg.snapshot().edges());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_snapshot_compaction_keeps_the_matching() {
        // SAVE-style compaction in sharded mode: the matching is sliced
        // across members and reassembled on recovery
        let (p, dir) = persistence("shardsave");
        p.set_snapshot_shards(3);
        let g = crate::graph::gen::Family::Uniform.generate(400, 9);
        let m = crate::matching::init::InitHeuristic::Cheap.run(&g);
        p.record_load("g", &g, 0).unwrap();
        p.record_snapshot("g", &g, 1, Some(&m)).unwrap();
        let sets = p.shard_snapshot_sets("g");
        assert_eq!(sets.len(), 1, "compaction must prune the older shard set");
        assert_eq!(sets[0].0, 1);
        let rec = p.recover_graph("g").unwrap().unwrap();
        assert_eq!(rec.snapshot_version, 1);
        assert_eq!(rec.matching.as_ref(), Some(&m));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incomplete_shard_set_falls_back_to_an_older_anchor() {
        let (p, dir) = persistence("shardpart");
        let g0 = from_edges(2, 2, &[(0, 0)]);
        p.record_load("g", &g0, 0).unwrap(); // single-file anchor at v0
        // a newer sharded snapshot lands, but one member goes missing
        p.set_snapshot_shards(2);
        let g1 = from_edges(2, 2, &[(0, 0), (1, 1)]);
        p.record_snapshot("g", &g1, 1, None).unwrap();
        // record_snapshot pruned the v0 single file; restore it to model
        // "older anchor still present, newest set damaged"
        snapshot::write_snapshot(&p.snap_path("g", 0), 0, &g0, None).unwrap();
        let member = p.shard_snap_path("g", 1, 1, 2);
        std::fs::remove_file(&member).unwrap();
        let rec = p.recover_graph("g").unwrap().expect("falls back to v0");
        assert_eq!(rec.snapshot_version, 0, "damaged set must not anchor");
        // with the member restored the set anchors again, beating v0
        snapshot::write_shard_snapshot(&member, 1, &g1, None, 1, 2, 1..2).unwrap();
        let rec = p.recover_graph("g").unwrap().unwrap();
        assert_eq!(rec.snapshot_version, 1);
        let mut got = rec.graph;
        assert_eq!(got.snapshot().edges(), vec![(0, 0), (1, 1)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_removes_a_sharded_graphs_files() {
        let (p, dir) = persistence("sharddrop");
        p.set_snapshot_shards(4);
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2)]);
        p.record_load("g", &g, 0).unwrap();
        assert!(p.record_drop("g", Some(0)).unwrap());
        assert!(p.shard_snapshot_sets("g").is_empty(), "members must be deleted");
        assert!(p.recover_graph("g").unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
