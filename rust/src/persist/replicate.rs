//! WAL-stream replication: ship the per-graph frame stream to follower
//! processes so a hot standby is always a seeded-repair away from a
//! certified matching.
//!
//! ## Protocol
//!
//! A follower dials the primary's normal verb port and sends
//! `REPLICA epoch=<e>`. The primary compares epochs (see *Fencing*): if
//! the follower's is **not higher**, it replies `OK epoch=<local>` and
//! upgrades the connection to a one-way event stream; otherwise it
//! replies `ERR fenced: ...` and marks *itself* read-only.
//!
//! Events are text lines (binary payloads hex-encoded):
//!
//! ```text
//! EV seq=<n> kind=snap  name=<enc> data=<hex snapshot image>
//! EV seq=<n> kind=frame name=<enc> data=<hex wal frame>
//! ```
//!
//! `snap` carries a full [`super::snapshot`] byte image — sent as the
//! per-graph baseline right after the handshake and whenever a `LOAD`
//! re-bases a name. `frame` carries one [`super::wal`] frame exactly as
//! appended to the primary's log; the follower replays it through
//! [`super::apply_update_frame`], the same incarnation-scoped kernel
//! crash recovery uses, so the ≤-version skip and gap-halt semantics are
//! identical on both paths. The follower answers `ACK seq=<n>` after
//! each event it has applied (and, when durable, persisted).
//!
//! ## Acked offsets and quorum
//!
//! The [`Hub`] stamps every published event with a global sequence
//! number and tracks the highest acknowledged one. Under
//! `--ack-mode quorum` the primary blocks each write verb until some
//! follower has acked its event (or fails the verb with
//! `JobError::Replication` after a timeout — the write stays locally
//! durable and is reported as in-doubt, never silently lost).
//!
//! ## Fencing
//!
//! Promotion bumps the node **epoch** (persisted in `<data-dir>/epoch`)
//! past anything the follower ever saw from its primary, and re-bases
//! every graph into a fresh incarnation of the `version >> 32` space. A
//! rejoining ex-primary that receives a `REPLICA` handshake carrying a
//! higher epoch knows a promotion happened behind its back: it refuses
//! the stream *and fences itself* (writes rejected) so it cannot
//! split-brain.

use crate::sanitize::lockorder::{self, LockClass};
use std::fs::{self, File};
use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How a primary decides an UPDATE/LOAD/DROP is "acked".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AckMode {
    /// acked once the local WAL fsync lands (single-node durability)
    #[default]
    Local,
    /// acked only after at least one follower confirms it applied the
    /// event — a primary-death failover then cannot lose it
    Quorum,
}

impl AckMode {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "local" => Some(AckMode::Local),
            "quorum" => Some(AckMode::Quorum),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AckMode::Local => "local",
            AckMode::Quorum => "quorum",
        }
    }
}

/// What this process currently is in the replication topology. Shared
/// (via `Arc`) between the executor, the server's verb handlers, and the
/// follower tailer thread; every field is independently atomic.
#[derive(Debug, Default)]
pub struct NodeRole {
    /// replica mode: write verbs rejected with `JobError::ReadOnly`
    pub read_only: AtomicBool,
    /// an ex-primary that learned (via a higher-epoch handshake) that it
    /// was failed over: write verbs rejected until an operator PROMOTEs
    pub fenced: AtomicBool,
    /// this node's fencing epoch (persisted in `<data-dir>/epoch`)
    pub epoch: AtomicU64,
    /// highest epoch ever observed from a peer (handshakes either way);
    /// promotion bumps past it
    pub primary_epoch_seen: AtomicU64,
    /// set by PROMOTE; the tailer thread exits when it sees this
    pub promoted: AtomicBool,
    /// the tailer currently holds a live stream to the primary
    pub tailer_connected: AtomicBool,
}

impl NodeRole {
    pub fn new() -> Self {
        Self::default()
    }

    /// Writes are allowed only on an unfenced primary.
    pub fn is_writable(&self) -> bool {
        !self.read_only.load(Ordering::Relaxed) && !self.fenced.load(Ordering::Relaxed)
    }

    pub fn is_replica(&self) -> bool {
        self.read_only.load(Ordering::Relaxed)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }
}

fn epoch_path(dir: &Path) -> PathBuf {
    dir.join("epoch")
}

/// Read the persisted fencing epoch; a missing or unparsable file is
/// epoch 0 (a never-promoted node).
pub fn read_epoch(dir: &Path) -> u64 {
    fs::read_to_string(epoch_path(dir))
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Durably persist the fencing epoch (tmp + rename + dir fsync — same
/// discipline as snapshots; the filename has no `.wal`/`.snap` suffix so
/// the graph-name scan never sees it).
pub fn write_epoch(dir: &Path, epoch: u64) -> io::Result<()> {
    let tmp = dir.join("epoch.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(format!("{epoch}\n").as_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, epoch_path(dir))?;
    File::open(dir)?.sync_all()
}

const HEX: &[u8; 16] = b"0123456789abcdef";

/// Lowercase hex, for shipping binary frame/snapshot bytes in the
/// line-oriented protocol.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0xf) as usize] as char);
    }
    out
}

/// Inverse of [`to_hex`]; `None` on odd length or a non-hex digit.
pub fn from_hex(s: &str) -> Option<Vec<u8>> {
    fn val(c: u8) -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    }
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((val(pair[0])? << 4) | val(pair[1])?);
    }
    Some(out)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// a full snapshot image: baseline sync or a `LOAD` re-base
    Snap,
    /// one WAL frame, byte-identical to the primary's log append
    Frame,
}

impl EventKind {
    fn name(&self) -> &'static str {
        match self {
            EventKind::Snap => "snap",
            EventKind::Frame => "frame",
        }
    }
}

/// One replication stream event.
#[derive(Debug, Clone)]
pub struct Event {
    pub seq: u64,
    pub kind: EventKind,
    /// decoded graph name
    pub name: String,
    /// snapshot image or WAL frame bytes
    pub data: Vec<u8>,
}

/// Render an event line (no trailing newline).
pub fn render_event(ev: &Event) -> String {
    format!(
        "EV seq={} kind={} name={} data={}",
        ev.seq,
        ev.kind.name(),
        super::encode_name(&ev.name),
        to_hex(&ev.data)
    )
}

/// Parse an `EV ...` line; `None` for anything malformed (the tailer
/// drops the connection and resyncs rather than guessing).
pub fn parse_event(line: &str) -> Option<Event> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("EV") {
        return None;
    }
    let (mut seq, mut kind, mut name, mut data) = (None, None, None, None);
    for part in parts {
        let (k, v) = part.split_once('=')?;
        match k {
            "seq" => seq = v.parse::<u64>().ok(),
            "kind" => {
                kind = match v {
                    "snap" => Some(EventKind::Snap),
                    "frame" => Some(EventKind::Frame),
                    _ => None,
                }
            }
            "name" => name = super::decode_name(v),
            "data" => data = from_hex(v),
            _ => return None,
        }
    }
    Some(Event { seq: seq?, kind: kind?, name: name?, data: data? })
}

/// Parse an `ACK seq=<n>` line from a follower.
pub fn parse_ack(line: &str) -> Option<u64> {
    let mut parts = line.split_whitespace();
    if parts.next() != Some("ACK") {
        return None;
    }
    parts.next()?.strip_prefix("seq=")?.parse().ok()
}

struct Subscriber {
    id: u64,
    tx: mpsc::Sender<String>,
}

#[derive(Default)]
struct HubState {
    /// last assigned sequence number (first published event gets 1)
    last_seq: u64,
    /// highest seq any follower has acknowledged
    max_acked: u64,
    next_sub_id: u64,
    subs: Vec<Subscriber>,
}

/// Primary-side frame shipper: assigns global sequence numbers, fans
/// published events out to every connected follower, and tracks the
/// acked high-water mark that quorum writes block on.
#[derive(Default)]
pub struct Hub {
    state: Mutex<HubState>,
    acked: Condvar,
}

impl Hub {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn subscriber_count(&self) -> usize {
        lockorder::lock(LockClass::Hub, &self.state).subs.len()
    }

    /// Last published sequence number ("frames shipped", for LAG).
    pub fn last_seq(&self) -> u64 {
        lockorder::lock(LockClass::Hub, &self.state).last_seq
    }

    pub fn max_acked(&self) -> u64 {
        lockorder::lock(LockClass::Hub, &self.state).max_acked
    }

    /// Published-but-unacked event count.
    pub fn lag(&self) -> u64 {
        let st = lockorder::lock(LockClass::Hub, &self.state);
        st.last_seq.saturating_sub(st.max_acked)
    }

    /// Register a follower stream. Returns `(floor_seq, id, rx)`: the
    /// subscriber sees every event published *after* this call via `rx`,
    /// and the caller tags the baseline snapshots it sends next with
    /// `floor_seq` — acking those cannot claim credit for any event the
    /// baseline might not cover.
    pub fn subscribe(&self) -> (u64, u64, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        let mut st = lockorder::lock(LockClass::Hub, &self.state);
        st.next_sub_id += 1;
        let id = st.next_sub_id;
        st.subs.push(Subscriber { id, tx });
        (st.last_seq, id, rx)
    }

    pub fn unsubscribe(&self, id: u64) {
        lockorder::lock(LockClass::Hub, &self.state).subs.retain(|s| s.id != id);
    }

    /// Publish one event to every live follower; returns its seq. The
    /// caller holds whatever lock orders this graph's events (the store
    /// entry mutex for updates, the name lock for load/drop), so per-
    /// graph sequence order matches commit order.
    pub fn publish(&self, kind: EventKind, name: &str, data: Vec<u8>) -> u64 {
        let mut st = lockorder::lock(LockClass::Hub, &self.state);
        st.last_seq += 1;
        let seq = st.last_seq;
        let line = format!(
            "{}\n",
            render_event(&Event { seq, kind, name: name.to_string(), data })
        );
        st.subs.retain(|s| s.tx.send(line.clone()).is_ok());
        seq
    }

    /// Record a follower acknowledgement.
    pub fn ack(&self, seq: u64) {
        let mut st = lockorder::lock(LockClass::Hub, &self.state);
        if seq > st.max_acked {
            st.max_acked = seq;
        }
        drop(st);
        self.acked.notify_all();
    }

    /// Block until some follower has acked `seq` (quorum write barrier);
    /// `false` on timeout.
    pub fn wait_acked(&self, seq: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        // the condvar wait consumes the raw guard, so the watchdog token
        // is held standalone for the whole wait (reacquisitions after a
        // wakeup are the same class at the same site — no new edges)
        let _token = lockorder::acquire(LockClass::Hub);
        let mut st = self.state.lock().unwrap();
        while st.max_acked < seq {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.acked.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }
}

/// What a timeout-safe line read produced.
pub enum LineIo {
    Line(String),
    /// read timeout elapsed with no complete line; any partial bytes
    /// stay buffered for the next call
    Idle,
    Eof,
    /// the cap was exceeded before a newline arrived
    TooLong,
}

/// Line reader that survives read timeouts without losing data.
/// `BufRead::read_line` discards partially-read bytes when the
/// underlying socket times out (its append guard truncates on `Err`),
/// which makes it unusable on a socket polled with `set_read_timeout`;
/// this accumulates across calls instead. Also enforces the server's
/// max-line cap.
pub struct LineReader<R> {
    inner: R,
    pending: Vec<u8>,
}

impl<R: BufRead> LineReader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner, pending: Vec::new() }
    }

    /// Next complete line (without the terminator), or why there isn't
    /// one. `max_len` of 0 means uncapped.
    pub fn next_line(&mut self, max_len: usize) -> io::Result<LineIo> {
        loop {
            if let Some(pos) = self.pending.iter().position(|&b| b == b'\n') {
                if max_len > 0 && pos > max_len {
                    // the cap applies even when the newline arrived in the
                    // same read as the oversized payload
                    self.pending = self.pending.split_off(pos + 1);
                    return Ok(LineIo::TooLong);
                }
                let rest = self.pending.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.pending, rest);
                line.pop(); // \n
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                return Ok(LineIo::Line(String::from_utf8_lossy(&line).into_owned()));
            }
            if max_len > 0 && self.pending.len() > max_len {
                self.pending.clear();
                return Ok(LineIo::TooLong);
            }
            let n = match self.inner.fill_buf() {
                Ok(b) if b.is_empty() => return Ok(LineIo::Eof),
                Ok(b) => {
                    self.pending.extend_from_slice(b);
                    b.len()
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(LineIo::Idle)
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            self.inner.consume(n);
        }
    }
}

/// Follower tailer configuration.
pub struct TailerCfg {
    /// primary's verb address (`host:port`)
    pub primary: String,
    pub role: Arc<NodeRole>,
    /// the server's stop handle: the tailer exits when it is set
    pub shutdown: Arc<AtomicBool>,
    /// where to persist an epoch adopted from the primary (the
    /// follower's data dir), when durable
    pub epoch_dir: Option<PathBuf>,
    /// event log for tailer connect/disconnect lifecycle (optional so
    /// embedded tailers can run without one)
    pub obs: Option<Arc<crate::obs::Obs>>,
}

enum StreamEnd {
    /// the primary told us we outrank it — we were promoted; stop
    Fenced,
    /// connection lost or stream error: reconnect with backoff
    Disconnected,
    /// an event failed to apply (version gap, bad decode): reconnect to
    /// force a fresh baseline
    ApplyError,
}

/// Follower-side tailer: connect, handshake, apply the event stream,
/// ack; on any failure reconnect with exponential backoff (100 ms
/// doubling to 5 s) until shutdown, promotion, or a fencing reply.
/// `apply` installs one event into the local store and returns `Err` to
/// force a resync.
pub fn run_tailer<F>(cfg: &TailerCfg, mut apply: F)
where
    F: FnMut(&Event) -> Result<(), String>,
{
    let mut backoff = Duration::from_millis(100);
    loop {
        if should_exit(cfg) {
            return;
        }
        let end = stream_once(cfg, &mut apply);
        let was_streaming = cfg.role.tailer_connected.swap(false, Ordering::Relaxed);
        if was_streaming {
            if let Some(o) = &cfg.obs {
                let reason = match &end {
                    Ok(StreamEnd::Fenced) => "fenced",
                    Ok(StreamEnd::Disconnected) => "disconnected",
                    Ok(StreamEnd::ApplyError) => "apply_error",
                    Err(_) => "io_error",
                };
                o.event(crate::obs::Level::Warn, "tailer_disconnect")
                    .field("primary", &cfg.primary)
                    .field("reason", reason)
                    .emit();
            }
        }
        if matches!(end, Ok(StreamEnd::Fenced)) {
            return;
        }
        if was_streaming {
            backoff = Duration::from_millis(100);
        }
        let mut waited = Duration::ZERO;
        while waited < backoff {
            if should_exit(cfg) {
                return;
            }
            let step = Duration::from_millis(25).min(backoff - waited);
            std::thread::sleep(step);
            waited += step;
        }
        backoff = (backoff * 2).min(Duration::from_secs(5));
    }
}

fn should_exit(cfg: &TailerCfg) -> bool {
    cfg.shutdown.load(Ordering::Relaxed) || cfg.role.promoted.load(Ordering::Relaxed)
}

fn stream_once<F>(cfg: &TailerCfg, apply: &mut F) -> io::Result<StreamEnd>
where
    F: FnMut(&Event) -> Result<(), String>,
{
    let mut stream = TcpStream::connect(&cfg.primary)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut lines = LineReader::new(BufReader::new(stream.try_clone()?));
    let epoch = cfg.role.epoch();
    stream.write_all(format!("REPLICA epoch={epoch}\n").as_bytes())?;
    let reply = loop {
        match lines.next_line(0)? {
            LineIo::Line(l) => break l,
            LineIo::Idle | LineIo::Eof | LineIo::TooLong => return Ok(StreamEnd::Disconnected),
        }
    };
    if reply.starts_with("ERR") {
        if reply.contains("fenced") {
            return Ok(StreamEnd::Fenced);
        }
        return Ok(StreamEnd::Disconnected);
    }
    if let Some(e) = reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix("epoch="))
        .and_then(|v| v.parse::<u64>().ok())
    {
        cfg.role.primary_epoch_seen.fetch_max(e, Ordering::Relaxed);
        if e > cfg.role.epoch() {
            cfg.role.epoch.store(e, Ordering::Relaxed);
            if let Some(dir) = &cfg.epoch_dir {
                let _ = write_epoch(dir, e);
            }
        }
    }
    cfg.role.tailer_connected.store(true, Ordering::Relaxed);
    if let Some(o) = &cfg.obs {
        o.event(crate::obs::Level::Info, "tailer_connect")
            .field("primary", &cfg.primary)
            .field_u64("epoch", cfg.role.epoch())
            .emit();
    }
    // short timeout from here on so shutdown/promotion are noticed fast
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    loop {
        if should_exit(cfg) {
            return Ok(StreamEnd::Disconnected);
        }
        match lines.next_line(0)? {
            LineIo::Idle => continue,
            LineIo::Eof | LineIo::TooLong => return Ok(StreamEnd::Disconnected),
            LineIo::Line(l) => {
                let Some(ev) = parse_event(&l) else {
                    return Ok(StreamEnd::ApplyError);
                };
                match apply(&ev) {
                    Ok(()) => {
                        stream.write_all(format!("ACK seq={}\n", ev.seq).as_bytes())?;
                    }
                    Err(_) => return Ok(StreamEnd::ApplyError),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrips() {
        for bytes in [&b""[..], &b"\x00\xff\x10abc"[..], &[0u8, 1, 2, 254, 255][..]] {
            assert_eq!(from_hex(&to_hex(bytes)).as_deref(), Some(bytes));
        }
        assert_eq!(from_hex("abc"), None, "odd length");
        assert_eq!(from_hex("zz"), None, "non-hex digit");
        assert_eq!(from_hex("AbCd"), Some(vec![0xab, 0xcd]), "uppercase tolerated");
    }

    #[test]
    fn event_lines_roundtrip() {
        let ev = Event {
            seq: 42,
            kind: EventKind::Frame,
            name: "dots.and spaces".to_string(),
            data: vec![0, 1, 255, 16],
        };
        let line = render_event(&ev);
        assert!(!line.contains('\n'));
        let back = parse_event(&line).expect("valid line");
        assert_eq!(back.seq, 42);
        assert_eq!(back.kind, EventKind::Frame);
        assert_eq!(back.name, ev.name);
        assert_eq!(back.data, ev.data);
        assert!(parse_event("EV seq=1 kind=wat name=g data=00").is_none());
        assert!(parse_event("NOPE seq=1").is_none());
        assert_eq!(parse_ack("ACK seq=7"), Some(7));
        assert_eq!(parse_ack("ACK"), None);
    }

    #[test]
    fn epoch_file_roundtrips_and_defaults_to_zero() {
        let dir = super::super::tests::tempdir("epoch");
        assert_eq!(read_epoch(&dir), 0);
        write_epoch(&dir, 7).unwrap();
        assert_eq!(read_epoch(&dir), 7);
        write_epoch(&dir, 8).unwrap();
        assert_eq!(read_epoch(&dir), 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hub_sequences_fans_out_and_tracks_acks() {
        let hub = Hub::new();
        assert_eq!(hub.publish(EventKind::Frame, "g", vec![1]), 1);
        let (floor, id, rx) = hub.subscribe();
        assert_eq!(floor, 1, "baseline floor is the pre-subscribe high-water mark");
        assert_eq!(hub.subscriber_count(), 1);
        let seq = hub.publish(EventKind::Snap, "g", vec![2]);
        assert_eq!(seq, 2);
        let line = rx.try_recv().expect("event fanned out");
        let ev = parse_event(line.trim()).unwrap();
        assert_eq!(ev.seq, 2);
        assert_eq!(ev.kind, EventKind::Snap);
        assert!(!hub.wait_acked(2, Duration::from_millis(20)), "nothing acked yet");
        assert_eq!(hub.lag(), 1);
        hub.ack(2);
        assert!(hub.wait_acked(2, Duration::from_millis(20)));
        assert_eq!(hub.lag(), 0);
        hub.ack(1); // stale ack never regresses the mark
        assert_eq!(hub.max_acked(), 2);
        hub.unsubscribe(id);
        assert_eq!(hub.subscriber_count(), 0);
        hub.publish(EventKind::Frame, "g", vec![3]); // no panic on empty fan-out
    }

    #[test]
    fn line_reader_splits_caps_and_reports_eof() {
        let data = b"first\nsecond\r\nlast";
        let mut r = LineReader::new(io::BufReader::new(&data[..]));
        let LineIo::Line(l) = r.next_line(64).unwrap() else { panic!("line") };
        assert_eq!(l, "first");
        let LineIo::Line(l) = r.next_line(64).unwrap() else { panic!("line") };
        assert_eq!(l, "second", "CRLF tolerated");
        assert!(matches!(r.next_line(64).unwrap(), LineIo::Eof), "no newline at EOF");
        let long = b"aaaaaaaaaaaaaaaaaaaa\nok\n";
        let mut r = LineReader::new(io::BufReader::new(&long[..]));
        assert!(matches!(r.next_line(4).unwrap(), LineIo::TooLong));
    }

    #[test]
    fn ack_mode_parses() {
        assert_eq!(AckMode::from_name("local"), Some(AckMode::Local));
        assert_eq!(AckMode::from_name("quorum"), Some(AckMode::Quorum));
        assert_eq!(AckMode::from_name("both"), None);
        assert_eq!(AckMode::Quorum.name(), "quorum");
    }
}
