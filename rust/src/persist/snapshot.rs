//! Graph snapshots: one file per (name, version) holding the fully
//! materialized [`BipartiteCsr`], its structural version, and — when one
//! was maintained — the cached maximum matching, so recovery can seed a
//! repair instead of recomputing.
//!
//! ## File layout (all integers little-endian)
//!
//! ```text
//! magic  "BMSNAP1\0"
//! body   version: u64
//!        nr: u64, nc: u64
//!        cxadj_len: u64, cxadj: [u32]
//!        cadj_len:  u64, cadj:  [u32]
//!        has_matching: u8  (0|1)
//!        [cmatch_len: u64, cmatch: [i32]]   (iff has_matching)
//! sum    fnv1a64(body): u64
//! ```
//!
//! Only the column-side CSR is stored; the row-side transpose is
//! recomputed on load (`BipartiteCsr::from_col_csr`). `rmatch` likewise
//! derives from `cmatch`. Writes go to a `.tmp` sibling, fsync, then
//! atomically rename — a crash never leaves a half-written file under
//! the real name, and whatever *is* under the real name still has its
//! checksum verified on read ([`read_snapshot`] returns `None` rather
//! than trusting a corrupt body).

use super::fnv1a64;
use crate::graph::csr::BipartiteCsr;
use crate::matching::{Matching, UNMATCHED};
use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"BMSNAP1\0";

/// A decoded snapshot file.
pub struct Snapshot {
    pub version: u64,
    pub graph: BipartiteCsr,
    pub matching: Option<Matching>,
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32s(out: &mut Vec<u8>, v: &[u32]) {
    push_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// The complete snapshot byte image (magic + body + checksum) — the
/// exact content [`write_snapshot`] persists, also shipped verbatim over
/// the replication stream so followers install through the same
/// checksummed decode path as crash recovery.
pub fn encode_snapshot(version: u64, g: &BipartiteCsr, matching: Option<&Matching>) -> Vec<u8> {
    let mut body = Vec::with_capacity(64 + 4 * (g.cxadj.len() + g.cadj.len()));
    push_u64(&mut body, version);
    push_u64(&mut body, g.nr as u64);
    push_u64(&mut body, g.nc as u64);
    push_u32s(&mut body, &g.cxadj);
    push_u32s(&mut body, &g.cadj);
    match matching {
        Some(m) => {
            body.push(1);
            push_u64(&mut body, m.cmatch.len() as u64);
            for &x in &m.cmatch {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        None => body.push(0),
    }
    let sum = fnv1a64(&body);
    let mut out = Vec::with_capacity(MAGIC.len() + body.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Serialize and atomically install a snapshot at `path`.
pub fn write_snapshot(
    path: &Path,
    version: u64,
    g: &BipartiteCsr,
    matching: Option<&Matching>,
) -> io::Result<()> {
    let bytes = encode_snapshot(version, g, matching);
    let tmp = path.with_extension("snap.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    // fsync the directory so the rename itself is durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn u64(&mut self) -> Option<u64> {
        let b = self.bytes.get(self.at..self.at + 8)?;
        self.at += 8;
        Some(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u8(&mut self) -> Option<u8> {
        let b = *self.bytes.get(self.at)?;
        self.at += 1;
        Some(b)
    }

    fn u32s(&mut self, max: usize) -> Option<Vec<u32>> {
        let len = self.u64()? as usize;
        if len > max {
            return None;
        }
        let b = self.bytes.get(self.at..self.at + 4 * len)?;
        self.at += 4 * len;
        Some(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i32s(&mut self, max: usize) -> Option<Vec<i32>> {
        let len = self.u64()? as usize;
        if len > max {
            return None;
        }
        let b = self.bytes.get(self.at..self.at + 4 * len)?;
        self.at += 4 * len;
        Some(b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Sanity cap on decoded vector lengths: rejects corrupt length fields
/// before they turn into giant allocations (checksummed data should
/// never hit it, but the checksum is read *after* the body is walked).
const MAX_LEN: usize = 1 << 31;

/// Decode a snapshot; `Ok(None)` on any structural or checksum problem
/// (the caller falls back to an older snapshot or reports the graph
/// unrecoverable — a bad snapshot is data loss, never a panic).
pub fn read_snapshot(path: &Path) -> io::Result<Option<Snapshot>> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    }
    Ok(decode_snapshot(&bytes))
}

/// Decode a full snapshot byte image (as produced by
/// [`encode_snapshot`]); `None` on any structural or checksum problem.
pub fn decode_snapshot(bytes: &[u8]) -> Option<Snapshot> {
    if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
        return None;
    }
    let body = &bytes[MAGIC.len()..bytes.len() - 8];
    let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a64(body) != sum {
        return None;
    }
    let mut r = Reader { bytes: body, at: 0 };
    let version = r.u64()?;
    let nr = r.u64()? as usize;
    let nc = r.u64()? as usize;
    if nr > MAX_LEN || nc > MAX_LEN {
        return None;
    }
    let cxadj = r.u32s(MAX_LEN)?;
    let cadj = r.u32s(MAX_LEN)?;
    // structural invariants before handing to from_col_csr (which asserts)
    if cxadj.len() != nc + 1
        || cxadj.first() != Some(&0)
        || cxadj.windows(2).any(|w| w[0] > w[1])
        || *cxadj.last().unwrap() as usize != cadj.len()
        || cadj.iter().any(|&x| (x as usize) >= nr)
    {
        return None;
    }
    let has_matching = r.u8()?;
    let matching = if has_matching == 1 {
        let cmatch = r.i32s(MAX_LEN)?;
        decode_matching(nr, nc, cmatch)
    } else {
        None
    };
    if r.at != body.len() {
        return None; // trailing bytes inside a checksummed body
    }
    let graph = BipartiteCsr::from_col_csr(nr, nc, cxadj, cadj);
    if graph.validate().is_err() {
        return None;
    }
    Some(Snapshot { version, graph, matching })
}

/// Rebuild a [`Matching`] from a serialized `cmatch`, rejecting (→ the
/// graph recovers matchingless, next `MATCH` runs cold) anything
/// structurally inconsistent instead of panicking in `from_cmatch`.
fn decode_matching(nr: usize, nc: usize, cmatch: Vec<i32>) -> Option<Matching> {
    if cmatch.len() != nc {
        return None;
    }
    let mut rmatch = vec![UNMATCHED; nr];
    for (c, &r) in cmatch.iter().enumerate() {
        if r == UNMATCHED {
            continue;
        }
        if r < 0 || (r as usize) >= nr || rmatch[r as usize] != UNMATCHED {
            return None;
        }
        rmatch[r as usize] = c as i32;
    }
    Some(Matching { rmatch, cmatch })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;

    fn sample() -> (BipartiteCsr, Matching) {
        let g = from_edges(3, 4, &[(0, 0), (1, 1), (2, 2), (0, 3)]);
        let m = Matching::from_cmatch(3, vec![0, 1, 2, UNMATCHED]);
        (g, m)
    }

    #[test]
    fn roundtrip_with_and_without_matching() {
        let dir = super::super::tests::tempdir("snap");
        let (g, m) = sample();
        let p = dir.join("g.v42.snap");
        write_snapshot(&p, 42, &g, Some(&m)).unwrap();
        let s = read_snapshot(&p).unwrap().expect("valid snapshot");
        assert_eq!(s.version, 42);
        assert_eq!(s.graph, g);
        assert_eq!(s.matching.as_ref(), Some(&m));
        write_snapshot(&p, 43, &g, None).unwrap();
        let s = read_snapshot(&p).unwrap().unwrap();
        assert_eq!(s.version, 43);
        assert!(s.matching.is_none());
        assert!(!p.with_extension("snap.tmp").exists(), "tmp must be renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_encode_decode_roundtrip() {
        // the replication stream ships these bytes without touching disk
        let (g, m) = sample();
        let s = decode_snapshot(&encode_snapshot(7, &g, Some(&m))).expect("valid image");
        assert_eq!(s.version, 7);
        assert_eq!(s.graph, g);
        assert_eq!(s.matching, Some(m));
    }

    #[test]
    fn corruption_and_truncation_yield_none_not_panic() {
        let dir = super::super::tests::tempdir("snapbad");
        let (g, m) = sample();
        let p = dir.join("g.v1.snap");
        write_snapshot(&p, 1, &g, Some(&m)).unwrap();
        let good = std::fs::read(&p).unwrap();
        // every truncation of the file is rejected cleanly
        for cut in 0..good.len() {
            assert!(decode_snapshot(&good[..cut]).is_none(), "cut at {cut}");
        }
        // any single flipped byte is rejected (magic, body, or checksum)
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x01;
            assert!(decode_snapshot(&bad).is_none(), "flip at {i}");
        }
        assert!(read_snapshot(&dir.join("missing.snap")).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inconsistent_matching_recovers_graph_without_it() {
        // cmatch claiming two columns share a row decodes as "no
        // matching", not a panic and not a poisoned warm start
        assert!(decode_matching(2, 2, vec![0, 0]).is_none());
        assert!(decode_matching(2, 2, vec![5, UNMATCHED]).is_none());
        assert!(decode_matching(2, 2, vec![-7, UNMATCHED]).is_none());
        let m = decode_matching(2, 2, vec![1, UNMATCHED]).unwrap();
        assert_eq!(m.rmatch, vec![UNMATCHED, 0]);
    }
}
