//! Graph snapshots: one file per (name, version) holding the fully
//! materialized [`BipartiteCsr`], its structural version, and — when one
//! was maintained — the cached maximum matching, so recovery can seed a
//! repair instead of recomputing.
//!
//! ## File layout (all integers little-endian)
//!
//! ```text
//! magic  "BMSNAP1\0"
//! body   version: u64
//!        nr: u64, nc: u64
//!        cxadj_len: u64, cxadj: [u32]
//!        cadj_len:  u64, cadj:  [u32]
//!        has_matching: u8  (0|1)
//!        [cmatch_len: u64, cmatch: [i32]]   (iff has_matching)
//! sum    fnv1a64(body): u64
//! ```
//!
//! Only the column-side CSR is stored; the row-side transpose is
//! recomputed on load (`BipartiteCsr::from_col_csr`). `rmatch` likewise
//! derives from `cmatch`. Writes go to a `.tmp` sibling, fsync, then
//! atomically rename — a crash never leaves a half-written file under
//! the real name, and whatever *is* under the real name still has its
//! checksum verified on read ([`read_snapshot`] returns `None` rather
//! than trusting a corrupt body).

use super::fnv1a64;
use crate::graph::csr::BipartiteCsr;
use crate::matching::{Matching, UNMATCHED};
use std::fs::{self, File};
use std::io::{self, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"BMSNAP1\0";
/// Magic for one member of a per-shard snapshot *set* (see
/// [`ShardSnapshot`]); distinct from [`MAGIC`] so a shard file can never
/// be mistaken for a whole-graph snapshot (or vice versa) even if a
/// filename is mangled.
const SHARD_MAGIC: &[u8; 8] = b"BMSHRD1\0";

/// A decoded snapshot file.
pub struct Snapshot {
    pub version: u64,
    pub graph: BipartiteCsr,
    pub matching: Option<Matching>,
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32s(out: &mut Vec<u8>, v: &[u32]) {
    push_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// The complete snapshot byte image (magic + body + checksum) — the
/// exact content [`write_snapshot`] persists, also shipped verbatim over
/// the replication stream so followers install through the same
/// checksummed decode path as crash recovery.
pub fn encode_snapshot(version: u64, g: &BipartiteCsr, matching: Option<&Matching>) -> Vec<u8> {
    let mut body = Vec::with_capacity(64 + 4 * (g.cxadj.len() + g.cadj.len()));
    push_u64(&mut body, version);
    push_u64(&mut body, g.nr as u64);
    push_u64(&mut body, g.nc as u64);
    push_u32s(&mut body, &g.cxadj);
    push_u32s(&mut body, &g.cadj);
    match matching {
        Some(m) => {
            body.push(1);
            push_u64(&mut body, m.cmatch.len() as u64);
            for &x in &m.cmatch {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        None => body.push(0),
    }
    let sum = fnv1a64(&body);
    let mut out = Vec::with_capacity(MAGIC.len() + body.len() + 8);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&body);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Serialize and atomically install a snapshot at `path`.
pub fn write_snapshot(
    path: &Path,
    version: u64,
    g: &BipartiteCsr,
    matching: Option<&Matching>,
) -> io::Result<()> {
    write_bytes_atomic(path, &encode_snapshot(version, g, matching))
}

/// tmp-file + fsync + atomic rename + directory fsync — shared by the
/// whole-graph and per-shard snapshot writers.
fn write_bytes_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = path.with_extension("snap.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    // fsync the directory so the rename itself is durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl Reader<'_> {
    fn u64(&mut self) -> Option<u64> {
        let b = self.bytes.get(self.at..self.at + 8)?;
        self.at += 8;
        Some(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn u8(&mut self) -> Option<u8> {
        let b = *self.bytes.get(self.at)?;
        self.at += 1;
        Some(b)
    }

    fn u32s(&mut self, max: usize) -> Option<Vec<u32>> {
        let len = self.u64()? as usize;
        if len > max {
            return None;
        }
        let b = self.bytes.get(self.at..self.at + 4 * len)?;
        self.at += 4 * len;
        Some(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i32s(&mut self, max: usize) -> Option<Vec<i32>> {
        let len = self.u64()? as usize;
        if len > max {
            return None;
        }
        let b = self.bytes.get(self.at..self.at + 4 * len)?;
        self.at += 4 * len;
        Some(b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Sanity cap on decoded vector lengths: rejects corrupt length fields
/// before they turn into giant allocations (checksummed data should
/// never hit it, but the checksum is read *after* the body is walked).
const MAX_LEN: usize = 1 << 31;

/// Decode a snapshot; `Ok(None)` on any structural or checksum problem
/// (the caller falls back to an older snapshot or reports the graph
/// unrecoverable — a bad snapshot is data loss, never a panic).
pub fn read_snapshot(path: &Path) -> io::Result<Option<Snapshot>> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    }
    Ok(decode_snapshot(&bytes))
}

/// Decode a full snapshot byte image (as produced by
/// [`encode_snapshot`]); `None` on any structural or checksum problem.
pub fn decode_snapshot(bytes: &[u8]) -> Option<Snapshot> {
    if bytes.len() < MAGIC.len() + 8 || &bytes[..MAGIC.len()] != MAGIC {
        return None;
    }
    let body = &bytes[MAGIC.len()..bytes.len() - 8];
    let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a64(body) != sum {
        return None;
    }
    let mut r = Reader { bytes: body, at: 0 };
    let version = r.u64()?;
    let nr = r.u64()? as usize;
    let nc = r.u64()? as usize;
    if nr > MAX_LEN || nc > MAX_LEN {
        return None;
    }
    let cxadj = r.u32s(MAX_LEN)?;
    let cadj = r.u32s(MAX_LEN)?;
    // structural invariants before handing to from_col_csr (which asserts)
    if cxadj.len() != nc + 1
        || cxadj.first() != Some(&0)
        || cxadj.windows(2).any(|w| w[0] > w[1])
        || *cxadj.last().unwrap() as usize != cadj.len()
        || cadj.iter().any(|&x| (x as usize) >= nr)
    {
        return None;
    }
    let has_matching = r.u8()?;
    let matching = if has_matching == 1 {
        let cmatch = r.i32s(MAX_LEN)?;
        decode_matching(nr, nc, cmatch)
    } else {
        None
    };
    if r.at != body.len() {
        return None; // trailing bytes inside a checksummed body
    }
    let graph = BipartiteCsr::from_col_csr(nr, nc, cxadj, cadj);
    if graph.validate().is_err() {
        return None;
    }
    Some(Snapshot { version, graph, matching })
}

/// Rebuild a [`Matching`] from a serialized `cmatch`, rejecting (→ the
/// graph recovers matchingless, next `MATCH` runs cold) anything
/// structurally inconsistent instead of panicking in `from_cmatch`.
fn decode_matching(nr: usize, nc: usize, cmatch: Vec<i32>) -> Option<Matching> {
    if cmatch.len() != nc {
        return None;
    }
    let mut rmatch = vec![UNMATCHED; nr];
    for (c, &r) in cmatch.iter().enumerate() {
        if r == UNMATCHED {
            continue;
        }
        if r < 0 || (r as usize) >= nr || rmatch[r as usize] != UNMATCHED {
            return None;
        }
        rmatch[r as usize] = c as i32;
    }
    Some(Matching { rmatch, cmatch })
}

/// One member of a per-shard snapshot set: the column-range slice of a
/// graph that one simulated device owns (see `crate::shard`), stored as
/// its own checksummed file so a sharded store can persist each device's
/// partition independently while a single per-graph WAL covers them all.
///
/// ## File layout (all integers little-endian)
///
/// ```text
/// magic  "BMSHRD1\0"
/// body   version: u64
///        shard: u64, shards: u64
///        col_lo: u64, col_hi: u64          (owned columns: lo..hi)
///        nr: u64, nc: u64                  (FULL graph dimensions)
///        cxadj_len: u64, cxadj: [u32]      (local offsets, rebased to 0)
///        cadj_len:  u64, cadj:  [u32]      (rows of the owned columns)
///        has_matching: u8  (0|1)
///        [cmatch_len: u64, cmatch: [i32]]  (cmatch[lo..hi] slice)
/// sum    fnv1a64(body): u64
/// ```
///
/// [`assemble_shards`] re-concatenates a complete, contiguous set back
/// into one [`Snapshot`]; any missing, inconsistent, or overlapping
/// member invalidates the whole set (recovery then falls back to an
/// older anchor), because a partially assembled graph would silently
/// drop columns.
pub struct ShardSnapshot {
    pub version: u64,
    pub shard: u64,
    pub shards: u64,
    pub col_lo: u64,
    pub col_hi: u64,
    pub nr: u64,
    pub nc: u64,
    /// local column offsets for `col_lo..col_hi`, rebased to start at 0
    pub cxadj: Vec<u32>,
    pub cadj: Vec<u32>,
    /// `cmatch[col_lo..col_hi]` iff the set carries a matching
    pub cmatch: Option<Vec<i32>>,
}

/// The byte image of one shard member covering `cols` of `g`.
pub fn encode_shard_snapshot(
    version: u64,
    g: &BipartiteCsr,
    matching: Option<&Matching>,
    shard: usize,
    shards: usize,
    cols: std::ops::Range<usize>,
) -> Vec<u8> {
    let (lo, hi) = (cols.start, cols.end);
    debug_assert!(shard < shards && lo <= hi && hi <= g.nc);
    let base = g.cxadj[lo];
    let mut body = Vec::with_capacity(96 + 4 * (hi - lo + 1));
    push_u64(&mut body, version);
    push_u64(&mut body, shard as u64);
    push_u64(&mut body, shards as u64);
    push_u64(&mut body, lo as u64);
    push_u64(&mut body, hi as u64);
    push_u64(&mut body, g.nr as u64);
    push_u64(&mut body, g.nc as u64);
    push_u64(&mut body, (hi - lo + 1) as u64);
    for &x in &g.cxadj[lo..=hi] {
        body.extend_from_slice(&(x - base).to_le_bytes());
    }
    push_u32s(&mut body, &g.cadj[base as usize..g.cxadj[hi] as usize]);
    match matching {
        Some(m) => {
            body.push(1);
            push_u64(&mut body, (hi - lo) as u64);
            for &x in &m.cmatch[lo..hi] {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        None => body.push(0),
    }
    let sum = fnv1a64(&body);
    let mut out = Vec::with_capacity(SHARD_MAGIC.len() + body.len() + 8);
    out.extend_from_slice(SHARD_MAGIC);
    out.extend_from_slice(&body);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Serialize and atomically install one shard member at `path`.
pub fn write_shard_snapshot(
    path: &Path,
    version: u64,
    g: &BipartiteCsr,
    matching: Option<&Matching>,
    shard: usize,
    shards: usize,
    cols: std::ops::Range<usize>,
) -> io::Result<()> {
    write_bytes_atomic(path, &encode_shard_snapshot(version, g, matching, shard, shards, cols))
}

/// Decode one shard member; `Ok(None)` on any structural or checksum
/// problem (the member — and with it the whole set — cannot anchor).
pub fn read_shard_snapshot(path: &Path) -> io::Result<Option<ShardSnapshot>> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e),
    }
    Ok(decode_shard_snapshot(&bytes))
}

/// Decode a shard-member byte image (see [`encode_shard_snapshot`]).
pub fn decode_shard_snapshot(bytes: &[u8]) -> Option<ShardSnapshot> {
    if bytes.len() < SHARD_MAGIC.len() + 8 || &bytes[..SHARD_MAGIC.len()] != SHARD_MAGIC {
        return None;
    }
    let body = &bytes[SHARD_MAGIC.len()..bytes.len() - 8];
    let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv1a64(body) != sum {
        return None;
    }
    let mut r = Reader { bytes: body, at: 0 };
    let version = r.u64()?;
    let shard = r.u64()?;
    let shards = r.u64()?;
    let col_lo = r.u64()?;
    let col_hi = r.u64()?;
    let nr = r.u64()?;
    let nc = r.u64()?;
    if shards == 0
        || shard >= shards
        || col_lo > col_hi
        || col_hi > nc
        || nr > MAX_LEN as u64
        || nc > MAX_LEN as u64
    {
        return None;
    }
    let span = (col_hi - col_lo) as usize;
    let cxadj = r.u32s(MAX_LEN)?;
    let cadj = r.u32s(MAX_LEN)?;
    // the local slice must be a valid CSR fragment on its own, so a
    // corrupt member can never poison the assembled graph
    if cxadj.len() != span + 1
        || cxadj.first() != Some(&0)
        || cxadj.windows(2).any(|w| w[0] > w[1])
        || *cxadj.last().unwrap() as usize != cadj.len()
        || cadj.iter().any(|&x| (x as u64) >= nr)
    {
        return None;
    }
    let has_matching = r.u8()?;
    let cmatch = if has_matching == 1 {
        let m = r.i32s(MAX_LEN)?;
        if m.len() != span {
            return None;
        }
        Some(m)
    } else {
        None
    };
    if r.at != body.len() {
        return None; // trailing bytes inside a checksummed body
    }
    Some(ShardSnapshot { version, shard, shards, col_lo, col_hi, nr, nc, cxadj, cadj, cmatch })
}

/// Re-assemble a complete per-shard set into one [`Snapshot`]. `None`
/// unless the members agree on version/dimensions/shard count, their
/// indices are exactly `0..shards`, and their column ranges tile
/// `0..nc` contiguously. The matching survives only when *every* member
/// carries its slice (and the concatenation is structurally consistent);
/// otherwise the graph assembles matchingless, mirroring the
/// whole-snapshot contract.
pub fn assemble_shards(mut parts: Vec<ShardSnapshot>) -> Option<Snapshot> {
    let first = parts.first()?;
    let (version, shards, nr, nc) = (first.version, first.shards, first.nr, first.nc);
    if parts.len() as u64 != shards
        || parts
            .iter()
            .any(|p| p.version != version || p.shards != shards || p.nr != nr || p.nc != nc)
    {
        return None;
    }
    parts.sort_by_key(|p| p.shard);
    let mut cxadj = Vec::with_capacity(nc as usize + 1);
    cxadj.push(0u32);
    let mut cadj = Vec::new();
    let mut expect_lo = 0u64;
    for (s, p) in parts.iter().enumerate() {
        if p.shard != s as u64 || p.col_lo != expect_lo {
            return None; // duplicate index or a gap/overlap in coverage
        }
        expect_lo = p.col_hi;
        let base = cadj.len() as u64;
        for &x in &p.cxadj[1..] {
            let off = base + x as u64;
            if off > u32::MAX as u64 {
                return None;
            }
            cxadj.push(off as u32);
        }
        cadj.extend_from_slice(&p.cadj);
    }
    if expect_lo != nc {
        return None; // the last shard must end at the column count
    }
    let matching = if parts.iter().all(|p| p.cmatch.is_some()) {
        let mut cmatch = Vec::with_capacity(nc as usize);
        for p in &mut parts {
            cmatch.append(p.cmatch.as_mut().unwrap());
        }
        decode_matching(nr as usize, nc as usize, cmatch)
    } else {
        None
    };
    let graph = BipartiteCsr::from_col_csr(nr as usize, nc as usize, cxadj, cadj);
    if graph.validate().is_err() {
        return None;
    }
    Some(Snapshot { version, graph, matching })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;

    fn sample() -> (BipartiteCsr, Matching) {
        let g = from_edges(3, 4, &[(0, 0), (1, 1), (2, 2), (0, 3)]);
        let m = Matching::from_cmatch(3, vec![0, 1, 2, UNMATCHED]);
        (g, m)
    }

    #[test]
    fn roundtrip_with_and_without_matching() {
        let dir = super::super::tests::tempdir("snap");
        let (g, m) = sample();
        let p = dir.join("g.v42.snap");
        write_snapshot(&p, 42, &g, Some(&m)).unwrap();
        let s = read_snapshot(&p).unwrap().expect("valid snapshot");
        assert_eq!(s.version, 42);
        assert_eq!(s.graph, g);
        assert_eq!(s.matching.as_ref(), Some(&m));
        write_snapshot(&p, 43, &g, None).unwrap();
        let s = read_snapshot(&p).unwrap().unwrap();
        assert_eq!(s.version, 43);
        assert!(s.matching.is_none());
        assert!(!p.with_extension("snap.tmp").exists(), "tmp must be renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn in_memory_encode_decode_roundtrip() {
        // the replication stream ships these bytes without touching disk
        let (g, m) = sample();
        let s = decode_snapshot(&encode_snapshot(7, &g, Some(&m))).expect("valid image");
        assert_eq!(s.version, 7);
        assert_eq!(s.graph, g);
        assert_eq!(s.matching, Some(m));
    }

    #[test]
    fn corruption_and_truncation_yield_none_not_panic() {
        let dir = super::super::tests::tempdir("snapbad");
        let (g, m) = sample();
        let p = dir.join("g.v1.snap");
        write_snapshot(&p, 1, &g, Some(&m)).unwrap();
        let good = std::fs::read(&p).unwrap();
        // every truncation of the file is rejected cleanly
        for cut in 0..good.len() {
            assert!(decode_snapshot(&good[..cut]).is_none(), "cut at {cut}");
        }
        // any single flipped byte is rejected (magic, body, or checksum)
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x01;
            assert!(decode_snapshot(&bad).is_none(), "flip at {i}");
        }
        assert!(read_snapshot(&dir.join("missing.snap")).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn inconsistent_matching_recovers_graph_without_it() {
        // cmatch claiming two columns share a row decodes as "no
        // matching", not a panic and not a poisoned warm start
        assert!(decode_matching(2, 2, vec![0, 0]).is_none());
        assert!(decode_matching(2, 2, vec![5, UNMATCHED]).is_none());
        assert!(decode_matching(2, 2, vec![-7, UNMATCHED]).is_none());
        let m = decode_matching(2, 2, vec![1, UNMATCHED]).unwrap();
        assert_eq!(m.rmatch, vec![UNMATCHED, 0]);
    }

    /// Split a graph into `k` shard members along a ColPartition.
    fn split(g: &BipartiteCsr, m: Option<&Matching>, v: u64, k: usize) -> Vec<ShardSnapshot> {
        let part = crate::shard::ColPartition::new(g, k);
        (0..k)
            .map(|s| {
                decode_shard_snapshot(&encode_shard_snapshot(v, g, m, s, k, part.range(s)))
                    .expect("member roundtrips")
            })
            .collect()
    }

    #[test]
    fn shard_set_roundtrips_through_assembly() {
        let g = crate::graph::gen::Family::Kron.generate(400, 3);
        let m = crate::matching::init::InitHeuristic::Cheap.run(&g);
        for k in [1usize, 2, 3, 4, 8] {
            let s = assemble_shards(split(&g, Some(&m), 11, k)).expect("complete set");
            assert_eq!(s.version, 11);
            assert_eq!(s.graph, g, "k={k}");
            assert_eq!(s.matching.as_ref(), Some(&m), "k={k}");
        }
        // matchingless members assemble a matchingless snapshot
        let s = assemble_shards(split(&g, None, 12, 4)).unwrap();
        assert!(s.matching.is_none());
        assert_eq!(s.graph, g);
    }

    #[test]
    fn shard_member_corruption_and_truncation_yield_none() {
        let (g, m) = sample();
        let good = encode_shard_snapshot(5, &g, Some(&m), 0, 2, 0..2);
        assert!(decode_shard_snapshot(&good).is_some());
        for cut in 0..good.len() {
            assert!(decode_shard_snapshot(&good[..cut]).is_none(), "cut at {cut}");
        }
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x01;
            assert!(decode_shard_snapshot(&bad).is_none(), "flip at {i}");
        }
        // a whole-graph snapshot image is not a shard member and vice versa
        assert!(decode_shard_snapshot(&encode_snapshot(5, &g, None)).is_none());
        assert!(decode_snapshot(&good).is_none());
    }

    #[test]
    fn assemble_rejects_incomplete_or_inconsistent_sets() {
        let g = crate::graph::gen::Family::Uniform.generate(300, 7);
        let whole = split(&g, None, 3, 4);
        // missing member
        let mut parts = split(&g, None, 3, 4);
        parts.remove(2);
        assert!(assemble_shards(parts).is_none());
        // duplicate member index (and with it a coverage gap)
        let mut parts = split(&g, None, 3, 2);
        parts[1].shard = 0;
        assert!(assemble_shards(parts).is_none());
        // version mismatch across members
        let mut parts = split(&g, None, 3, 4);
        parts[3].version = 4;
        assert!(assemble_shards(parts).is_none());
        // shard-count mismatch
        let mut parts = split(&g, None, 3, 4);
        parts[0].shards = 5;
        assert!(assemble_shards(parts).is_none());
        // the untampered set still assembles
        assert_eq!(assemble_shards(whole).unwrap().graph, g);
    }
}
