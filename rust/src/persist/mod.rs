//! Durability for the server-side graph store: a per-graph write-ahead
//! log, piggybacked snapshots, and crash recovery that *repairs* instead
//! of recomputing.
//!
//! PR 4 made the coordinator stateful — named graphs live in
//! [`crate::coordinator::store::GraphStore`] and clients ship
//! [`crate::dynamic::DeltaBatch`] updates — but all of it evaporated on
//! restart. This layer persists the *deltas*, not just the result
//! (following the external-memory matching line of work: graph state that
//! outlives a process belongs on disk in a streamable format), so a
//! restarted server warm-starts from where it crashed:
//!
//! * [`wal`] — length-prefixed, checksummed frames appended to
//!   `<name>.wal` and fsync'd before an `UPDATE` is acknowledged. Update
//!   frames carry the batch in the **delta wire format** of
//!   `crate::dynamic::delta` (`addrows= addcols= add= del=` clauses —
//!   the canonical net form from [`DeltaBatch::net_from_report`]) plus
//!   the [`crate::dynamic::ApplyReport`] it produced, so replay can
//!   verify it reproduced the same net effect. A torn final frame (the
//!   crash case) fails its checksum and is dropped; everything before it
//!   is a consistent prefix.
//! * [`snapshot`] — the rebuilt [`crate::graph::csr::BipartiteCsr`]
//!   serialized together with its structural version and the cached
//!   maximum matching, written to `<name>.v<version>.snap` via
//!   tmp-file + atomic rename. Snapshots are triggered by the overlay's
//!   threshold CSR rebuild (the expensive materialization already
//!   happened — persisting it is marginal cost), by LRU eviction, and by
//!   the server's `SAVE` verb.
//! * [`recover`] — on startup (or on a `MATCH name=` miss after
//!   eviction) the data dir is scanned, the newest *valid* snapshot per
//!   graph is loaded, the WAL tail is replayed through
//!   [`crate::dynamic::DynamicGraph::apply`], and the matching is
//!   restored by [`crate::dynamic::repair`] seeded from the replayed
//!   exposed columns — recovery is a repair, not a recompute.
//!
//! Compaction: once a snapshot covers the log (same entry lock, so
//! nothing can interleave), the WAL is truncated to empty — recovery then
//! replays only frames newer than the snapshot version. Replay is
//! idempotent w.r.t. the snapshot: frames at or below the snapshot
//! version, and frames from an earlier incarnation of the name (version
//! ranges are disjoint per `LOAD` — the top 32 bits identify the
//! incarnation), are skipped.
//!
//! ## What is fsync'd when
//!
//! | event             | disk effect                                 | fsync before ack |
//! |-------------------|---------------------------------------------|------------------|
//! | `LOAD`            | base snapshot + WAL reset with LOAD marker  | yes              |
//! | `UPDATE` (ok)     | one WAL frame (net batch + report)          | yes              |
//! | `UPDATE` (ERR)    | nothing — rolled back in memory, not logged | —                |
//! | rebuild piggyback | snapshot + WAL truncation                   | best-effort      |
//! | `SAVE` / eviction | snapshot + WAL truncation                   | yes              |
//! | `DROP`            | DROP marker, then files deleted             | yes              |

pub mod recover;
pub mod replicate;
pub mod snapshot;
pub mod wal;

pub use recover::{apply_update_frame, FrameStep, GraphRecovery, RecoveredGraph, RecoveryReport};

use crate::dynamic::{ApplyReport, DeltaBatch};
use crate::graph::csr::BipartiteCsr;
use crate::matching::Matching;
use crate::sanitize::lockorder::{self, LockClass};
use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// FNV-1a 64-bit — the frame/snapshot checksum. Not cryptographic; it
/// detects torn writes and bit rot, which is the crash-consistency
/// contract (an adversarial data dir is out of scope).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Encode a graph name into a filesystem-safe stem: `[A-Za-z0-9_-]`
/// pass through, everything else becomes `%XX` (so `.` can never collide
/// with the `.v<version>.snap` / `.wal` suffixes).
pub fn encode_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for b in name.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'_' | b'-' => out.push(b as char),
            other => out.push_str(&format!("%{other:02X}")),
        }
    }
    out
}

/// Inverse of [`encode_name`]; `None` on malformed escapes.
pub fn decode_name(stem: &str) -> Option<String> {
    let bytes = stem.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = stem.get(i + 1..i + 3)?;
            out.push(u8::from_str_radix(hex, 16).ok()?);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).ok()
}

/// The WAL record an acknowledged update commits: the batch's *net*
/// effect in delta wire format plus the report it produced. Shared by
/// [`Persistence::append_update`] and the replication shipper so the
/// frame a follower replays is byte-identical to the one recovery
/// replays.
pub fn update_record(version_after: u64, report: &ApplyReport) -> wal::WalRecord {
    wal::WalRecord::Update {
        version_after,
        batch_wire: DeltaBatch::net_from_report(report).to_wire(),
        report_wire: report.to_wire(),
    }
}

/// The durability layer's handle: one per `--data-dir`, shared by every
/// executor clone. All file operations for a given graph name serialize
/// on a per-name lock, so multi-file transitions (snapshot + WAL
/// truncation, DROP marker + deletion) are never interleaved by a racing
/// verb on the same name.
pub struct Persistence {
    dir: PathBuf,
    name_locks: Mutex<HashMap<String, Arc<Mutex<()>>>>,
    /// snapshots are written as a set of this many per-shard files
    /// (`.v<version>.s<shard>of<shards>.snap`, column-partitioned like
    /// sharded execution) instead of one `.snap` when > 1; the WAL stays
    /// a single per-graph log either way. Read paths always accept both
    /// layouts, so flipping the knob between restarts is safe.
    snapshot_shards: std::sync::atomic::AtomicUsize,
}

impl Persistence {
    /// Open (creating if needed) a data directory.
    pub fn open(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(Self {
            dir,
            name_locks: Mutex::new(HashMap::new()),
            snapshot_shards: std::sync::atomic::AtomicUsize::new(1),
        })
    }

    /// Write future snapshots as `shards` per-shard files (1 = the
    /// single-file layout). Affects writes only; recovery reads whatever
    /// layout is on disk.
    pub fn set_snapshot_shards(&self, shards: usize) {
        self.snapshot_shards
            .store(shards.max(1), std::sync::atomic::Ordering::Relaxed);
    }

    pub fn snapshot_shards(&self) -> usize {
        self.snapshot_shards.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The per-name file-operation lock. The executor takes it explicitly
    /// (via the `*_locked` methods) when a transition must cover both the
    /// in-memory store map and the on-disk state — `DROP` (unmap + marker
    /// + deletion) and transparent reload (recover + install) — so a
    /// racing reload can neither resurrect a dropped graph nor clobber a
    /// fresh `LOAD`. Lock order: a store *entry* mutex, when held, is
    /// always taken before this lock (UPDATE's WAL append, eviction's
    /// snapshot, SAVE); this lock is never held while acquiring an entry
    /// mutex. Debug builds enforce exactly that through
    /// [`crate::sanitize::lockorder`] (`Entry → Name`, with the lock
    /// table itself a leaf).
    pub fn name_lock(&self, name: &str) -> Arc<Mutex<()>> {
        lockorder::lock(LockClass::NameTable, &self.name_locks)
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    fn lock_for(&self, name: &str) -> Arc<Mutex<()>> {
        self.name_lock(name)
    }

    /// Drop `name`'s lock-table entry if nobody else holds a handle to
    /// it. Called after a `DROP` completes so a churn workload of
    /// uniquely-named graphs does not grow the table without bound; a
    /// concurrently held handle (strong count > 1) keeps the entry —
    /// removal then would let two threads hold "the" name lock at once.
    pub fn release_name_lock_if_unused(&self, name: &str) {
        let mut locks = lockorder::lock(LockClass::NameTable, &self.name_locks);
        if locks.get(name).is_some_and(|l| Arc::strong_count(l) == 1) {
            locks.remove(name);
        }
    }

    /// The graph's WAL file (`<dir>/<encoded-name>.wal`). Public for
    /// observability and the crash-consistency tests, which truncate it
    /// at arbitrary byte boundaries.
    pub fn wal_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{}.wal", encode_name(name)))
    }

    pub(crate) fn snap_path(&self, name: &str, version: u64) -> PathBuf {
        self.dir.join(format!("{}.v{}.snap", encode_name(name), version))
    }

    /// One member of a per-shard snapshot set:
    /// `<name>.v<version>.s<shard>of<shards>.snap`. The `s<i>of<k>`
    /// infix fails [`Persistence::snapshots_of`]'s `u64` version parse,
    /// so the two layouts can never be confused by a directory scan.
    pub(crate) fn shard_snap_path(
        &self,
        name: &str,
        version: u64,
        shard: usize,
        shards: usize,
    ) -> PathBuf {
        self.dir
            .join(format!("{}.v{}.s{}of{}.snap", encode_name(name), version, shard, shards))
    }

    /// Every single-file `.snap` for `name`, as `(version, path)`,
    /// newest first. Per-shard members are excluded (their version field
    /// is not a bare integer); see
    /// [`Persistence::shard_snapshot_sets`] for those.
    pub(crate) fn snapshots_of(&self, name: &str) -> Vec<(u64, PathBuf)> {
        let prefix = format!("{}.v", encode_name(name));
        let mut out = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let fname = entry.file_name();
                let Some(fname) = fname.to_str() else { continue };
                if let Some(rest) = fname.strip_prefix(&prefix) {
                    if let Some(v) = rest.strip_suffix(".snap") {
                        if let Ok(version) = v.parse::<u64>() {
                            out.push((version, entry.path()));
                        }
                    }
                }
            }
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out
    }

    /// Per-shard snapshot sets for `name`, newest version first: each
    /// entry is `(version, members)` with members as
    /// `(shard, shards, path)` sorted by shard index. The scan groups by
    /// filename only — completeness and member integrity are judged at
    /// read time ([`snapshot::assemble_shards`]), so a half-written set
    /// surfaces as "present but not assemblable", exactly what recovery
    /// and `fsck` need to see.
    pub(crate) fn shard_snapshot_sets(
        &self,
        name: &str,
    ) -> Vec<(u64, Vec<(u64, u64, PathBuf)>)> {
        let prefix = format!("{}.v", encode_name(name));
        let mut by_version: HashMap<u64, Vec<(u64, u64, PathBuf)>> = HashMap::new();
        if let Ok(entries) = fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let fname = entry.file_name();
                let Some(fname) = fname.to_str() else { continue };
                let Some(rest) =
                    fname.strip_prefix(&prefix).and_then(|r| r.strip_suffix(".snap"))
                else {
                    continue;
                };
                // "<version>.s<shard>of<shards>"
                let Some((v, member)) = rest.split_once(".s") else { continue };
                let Some((s, k)) = member.split_once("of") else { continue };
                let (Ok(version), Ok(shard), Ok(shards)) =
                    (v.parse::<u64>(), s.parse::<u64>(), k.parse::<u64>())
                else {
                    continue;
                };
                by_version.entry(version).or_default().push((shard, shards, entry.path()));
            }
        }
        let mut out: Vec<_> = by_version.into_iter().collect();
        for (_, members) in &mut out {
            members.sort_by_key(|(s, _, _)| *s);
        }
        out.sort_by(|a, b| b.0.cmp(&a.0));
        out
    }

    /// Read and assemble the per-shard set at `version`; `Ok(None)` when
    /// any member is missing, corrupt, or inconsistent — the set as a
    /// whole cannot anchor a recovery then.
    pub(crate) fn read_shard_set(
        &self,
        members: &[(u64, u64, PathBuf)],
    ) -> io::Result<Option<snapshot::Snapshot>> {
        let mut parts = Vec::with_capacity(members.len());
        for (_, _, path) in members {
            match snapshot::read_shard_snapshot(path)? {
                Some(p) => parts.push(p),
                None => return Ok(None),
            }
        }
        Ok(snapshot::assemble_shards(parts))
    }

    /// Names with any on-disk state (WAL or snapshot), sorted.
    pub fn graph_names(&self) -> io::Result<Vec<String>> {
        let mut names = std::collections::BTreeSet::new();
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else { continue };
            let stem = if let Some(s) = fname.strip_suffix(".wal") {
                Some(s)
            } else if fname.ends_with(".snap") {
                // strip ".v<version>.snap"
                fname.rfind(".v").map(|i| &fname[..i])
            } else {
                None
            };
            if let Some(name) = stem.and_then(decode_name) {
                names.insert(name);
            }
        }
        Ok(names.into_iter().collect())
    }

    /// `LOAD` durability: persist the freshly installed base graph as the
    /// incarnation's first snapshot, prune older incarnations' snapshots,
    /// and reset the WAL to a single LOAD marker. Ordering matters for
    /// crash consistency — snapshot first, WAL reset second — so a crash
    /// between the two leaves the *new* snapshot plus the *old* WAL,
    /// whose frames replay filters out by incarnation.
    pub fn record_load(&self, name: &str, g: &BipartiteCsr, version_base: u64) -> io::Result<()> {
        let guard = self.lock_for(name);
        let _g = lockorder::lock(LockClass::Name, &guard);
        self.record_load_locked(name, g, version_base)
    }

    /// [`Persistence::record_load`] without taking the name lock — the
    /// executor's `LOAD` path holds it across persist + store install,
    /// so a concurrent `DROP` can never delete the just-written base out
    /// from under an acknowledged (but not yet installed) `LOAD`.
    pub fn record_load_locked(
        &self,
        name: &str,
        g: &BipartiteCsr,
        version_base: u64,
    ) -> io::Result<()> {
        self.write_snapshot_files_locked(name, g, version_base, None)?;
        self.prune_snapshots_locked(name, version_base);
        wal::reset_with(&self.wal_path(name), &wal::WalRecord::Load { version_base })
    }

    /// Write the snapshot for (`name`, `version`) in the configured
    /// layout: one `.snap` file, or — with
    /// [`Persistence::set_snapshot_shards`] > 1 — a set of per-shard
    /// members column-partitioned exactly like sharded execution
    /// ([`crate::shard::ColPartition`]). Member write order doesn't
    /// matter: each file is atomic on its own, and a crash mid-set
    /// leaves an incomplete set that read paths refuse to assemble.
    fn write_snapshot_files_locked(
        &self,
        name: &str,
        g: &BipartiteCsr,
        version: u64,
        matching: Option<&Matching>,
    ) -> io::Result<()> {
        let shards = self.snapshot_shards();
        if shards <= 1 {
            return snapshot::write_snapshot(&self.snap_path(name, version), version, g, matching);
        }
        let part = crate::shard::ColPartition::new(g, shards);
        for s in 0..shards {
            snapshot::write_shard_snapshot(
                &self.shard_snap_path(name, version, s, shards),
                version,
                g,
                matching,
                s,
                shards,
                part.range(s),
            )?;
        }
        Ok(())
    }

    /// `UPDATE` durability: append one frame — the batch's *net* effect
    /// in delta wire format plus the report — and fsync. Called before
    /// the client is acknowledged; an `Err` here fails (and rolls back)
    /// the update.
    pub fn append_update(
        &self,
        name: &str,
        version_after: u64,
        report: &ApplyReport,
    ) -> io::Result<()> {
        let guard = self.lock_for(name);
        let _g = lockorder::lock(LockClass::Name, &guard);
        wal::append(&self.wal_path(name), &update_record(version_after, report))
    }

    /// fsync every WAL in the data dir plus the directory itself — the
    /// graceful-shutdown belt-and-braces pass (each append already syncs,
    /// but this closes the window for anything the OS still buffers).
    pub fn sync_all(&self) -> io::Result<()> {
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let fname = entry.file_name();
            if fname.to_str().is_some_and(|f| f.ends_with(".wal")) {
                match fs::File::open(entry.path()) {
                    Ok(f) => f.sync_all()?,
                    // a racing DROP may delete a WAL mid-scan
                    Err(e) if e.kind() == io::ErrorKind::NotFound => {}
                    Err(e) => return Err(e),
                }
            }
        }
        fs::File::open(&self.dir)?.sync_all()
    }

    /// Snapshot the live state and compact: write
    /// `<name>.v<version>.snap`, prune older snapshots, truncate the WAL
    /// (every logged frame is ≤ `version`, hence covered). Triggered by
    /// threshold rebuilds, eviction, and `SAVE`.
    pub fn record_snapshot(
        &self,
        name: &str,
        g: &BipartiteCsr,
        version: u64,
        matching: Option<&Matching>,
    ) -> io::Result<()> {
        let guard = self.lock_for(name);
        let _g = lockorder::lock(LockClass::Name, &guard);
        self.write_snapshot_files_locked(name, g, version, matching)?;
        self.prune_snapshots_locked(name, version);
        wal::truncate(&self.wal_path(name))
    }

    /// Whether `name` has any on-disk state. Caller holds the name lock.
    pub fn has_state_locked(&self, name: &str) -> bool {
        self.wal_path(name).exists()
            || !self.snapshots_of(name).is_empty()
            || !self.shard_snapshot_sets(name).is_empty()
    }

    /// The `DROP` commit point: append a version-scoped DROP marker and
    /// fsync it. After this returns `Ok`, the drop is durable — recovery
    /// completes the deletion even if the process dies before
    /// [`Persistence::delete_graph_files_locked`] runs. `version` scopes
    /// the marker to the incarnation being dropped; `None` (graph not in
    /// memory) falls back to the newest snapshot's version. Caller holds
    /// the name lock.
    pub fn append_drop_marker_locked(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> io::Result<()> {
        let version = version
            .or_else(|| self.snapshots_of(name).first().map(|(v, _)| *v))
            .or_else(|| self.shard_snapshot_sets(name).first().map(|(v, _)| *v))
            .unwrap_or(0);
        wal::append(&self.wal_path(name), &wal::WalRecord::Drop { version })
    }

    /// Remove `name`'s WAL and snapshots (both single-file and per-shard
    /// layouts). Best-effort by design: the fsync'd DROP marker is the
    /// commit point, so a deletion that fails here is completed by the
    /// next recovery scan. Caller holds the name lock.
    pub fn delete_graph_files_locked(&self, name: &str) {
        for (_, p) in self.snapshots_of(name) {
            let _ = fs::remove_file(p);
        }
        for (_, members) in self.shard_snapshot_sets(name) {
            for (_, _, p) in members {
                let _ = fs::remove_file(p);
            }
        }
        let _ = fs::remove_file(self.wal_path(name));
    }

    /// `DROP` durability in one call (marker, then deletion), for callers
    /// that don't need to interleave the in-memory unmap under the same
    /// lock. Returns whether any on-disk state existed.
    pub fn record_drop(&self, name: &str, version: Option<u64>) -> io::Result<bool> {
        let guard = self.lock_for(name);
        let _g = lockorder::lock(LockClass::Name, &guard);
        if !self.has_state_locked(name) {
            return Ok(false);
        }
        self.append_drop_marker_locked(name, version)?;
        self.delete_graph_files_locked(name);
        drop(_g);
        drop(guard);
        self.release_name_lock_if_unused(name);
        Ok(true)
    }

    /// Reconstruct one graph from disk: newest valid snapshot + WAL tail
    /// replay. `Ok(None)` when nothing (or only a DROP) is on disk, or
    /// when no snapshot survives to anchor the replay.
    pub fn recover_graph(&self, name: &str) -> io::Result<Option<recover::RecoveredGraph>> {
        let guard = self.lock_for(name);
        let _g = lockorder::lock(LockClass::Name, &guard);
        recover::recover_graph(self, name)
    }

    /// [`Persistence::recover_graph`] without taking the name lock — for
    /// the executor's transparent-reload path, which must hold the lock
    /// across recover *and* store installation (a racing `DROP` or `LOAD`
    /// in the gap would otherwise be resurrected over / clobbered).
    pub fn recover_graph_locked(
        &self,
        name: &str,
    ) -> io::Result<Option<recover::RecoveredGraph>> {
        recover::recover_graph(self, name)
    }

    /// Remove all snapshots of `name` — single-file and per-shard —
    /// except `keep_version`'s. Callers hold the per-name lock.
    fn prune_snapshots_locked(&self, name: &str, keep_version: u64) {
        for (v, p) in self.snapshots_of(name) {
            if v != keep_version {
                let _ = fs::remove_file(p);
            }
        }
        for (v, members) in self.shard_snapshot_sets(name) {
            if v != keep_version {
                for (_, _, p) in members {
                    let _ = fs::remove_file(p);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_encoding_roundtrips_and_is_fs_safe() {
        for name in ["g", "web-01", "a/b", "dots.and.spaces in names", "naïve", "%wal", ""] {
            let enc = encode_name(name);
            assert!(
                enc.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'-' || b == b'%'),
                "{enc}"
            );
            assert!(!enc.contains('.'), "dots must be escaped: {enc}");
            assert_eq!(decode_name(&enc).as_deref(), Some(name));
        }
        assert_eq!(decode_name("%zz"), None);
        assert_eq!(decode_name("%4"), None);
    }

    #[test]
    fn fnv_is_stable() {
        // the on-disk format depends on this exact function: pin it
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn graph_names_scans_both_kinds() {
        let dir = tempdir("names");
        let p = Persistence::open(&dir).unwrap();
        std::fs::write(p.wal_path("alpha"), b"").unwrap();
        std::fs::write(p.snap_path("b.t", 7), b"").unwrap();
        std::fs::write(dir.join("unrelated.txt"), b"").unwrap();
        assert_eq!(p.graph_names().unwrap(), vec!["alpha".to_string(), "b.t".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    pub(super) fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_persist_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }
}
