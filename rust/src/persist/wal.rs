//! The per-graph write-ahead log: a flat file of length-prefixed,
//! checksummed frames, appended and fsync'd before the server
//! acknowledges the update that produced them.
//!
//! ## Frame layout
//!
//! ```text
//! [payload_len: u32 LE] [kind: u8] [payload: payload_len bytes] [fnv1a64(kind ‖ payload): u64 LE]
//! ```
//!
//! Three kinds:
//!
//! * `Load { version_base }` — the marker a (re-)`LOAD` leaves after
//!   resetting the log; the graph itself lives in the snapshot written
//!   just before (see `super::Persistence::record_load`).
//! * `Update { version_after, batch_wire, report_wire }` — one committed
//!   delta batch: the **already-wire-formatted** net batch
//!   (`crate::dynamic::DeltaBatch::to_wire`, the `addrows= addcols= add=
//!   del=` clause syntax of `dynamic::delta`) and the
//!   `crate::dynamic::ApplyReport` it produced (`ApplyReport::to_wire`),
//!   so replay can cross-check that re-applying reproduced the same net
//!   effect.
//! * `Drop { version }` — the graph was dropped; scoped to the
//!   incarnation (`version >> 32`) so a stale marker can never kill a
//!   later incarnation that reused the name.
//!
//! ## Torn tails
//!
//! A crash mid-append leaves a final frame that is short, length-mangled,
//! or checksum-broken. [`read_wal`] stops at the first such frame and
//! reports the tail as dropped — everything before it is a consistent
//! prefix, which is exactly the durability contract: an update is either
//! wholly in the log (it was acknowledged) or wholly absent (it never
//! was).

use super::fnv1a64;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

const KIND_LOAD: u8 = 1;
const KIND_UPDATE: u8 = 2;
const KIND_DROP: u8 = 3;

/// Guards against a corrupted length prefix making `read_wal` attempt a
/// multi-gigabyte allocation: no legitimate frame payload approaches
/// this (a batch of a million edges is ~12 MB of wire text).
const MAX_FRAME_PAYLOAD: usize = 256 << 20;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    Load { version_base: u64 },
    Update { version_after: u64, batch_wire: String, report_wire: String },
    Drop { version: u64 },
}

impl WalRecord {
    fn kind(&self) -> u8 {
        match self {
            WalRecord::Load { .. } => KIND_LOAD,
            WalRecord::Update { .. } => KIND_UPDATE,
            WalRecord::Drop { .. } => KIND_DROP,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            WalRecord::Load { version_base } => version_base.to_le_bytes().to_vec(),
            WalRecord::Drop { version } => version.to_le_bytes().to_vec(),
            WalRecord::Update { version_after, batch_wire, report_wire } => {
                let mut p = Vec::with_capacity(16 + batch_wire.len() + report_wire.len());
                p.extend_from_slice(&version_after.to_le_bytes());
                p.extend_from_slice(&(batch_wire.len() as u32).to_le_bytes());
                p.extend_from_slice(batch_wire.as_bytes());
                p.extend_from_slice(&(report_wire.len() as u32).to_le_bytes());
                p.extend_from_slice(report_wire.as_bytes());
                p
            }
        }
    }

    fn decode(kind: u8, payload: &[u8]) -> Option<WalRecord> {
        let u64_at = |at: usize| -> Option<u64> {
            payload.get(at..at + 8).map(|b| u64::from_le_bytes(b.try_into().unwrap()))
        };
        match kind {
            KIND_LOAD if payload.len() == 8 => {
                Some(WalRecord::Load { version_base: u64_at(0)? })
            }
            KIND_DROP if payload.len() == 8 => Some(WalRecord::Drop { version: u64_at(0)? }),
            KIND_UPDATE => {
                let version_after = u64_at(0)?;
                let blen =
                    u32::from_le_bytes(payload.get(8..12)?.try_into().unwrap()) as usize;
                let batch = payload.get(12..12 + blen)?;
                let at = 12 + blen;
                let rlen =
                    u32::from_le_bytes(payload.get(at..at + 4)?.try_into().unwrap()) as usize;
                let report = payload.get(at + 4..at + 4 + rlen)?;
                if at + 4 + rlen != payload.len() {
                    return None; // trailing garbage inside a framed payload
                }
                Some(WalRecord::Update {
                    version_after,
                    batch_wire: String::from_utf8(batch.to_vec()).ok()?,
                    report_wire: String::from_utf8(report.to_vec()).ok()?,
                })
            }
            _ => None,
        }
    }
}

/// One frame's bytes: length prefix + kind + payload + checksum.
pub fn encode_frame(rec: &WalRecord) -> Vec<u8> {
    let payload = rec.payload();
    let kind = rec.kind();
    let mut sum_input = Vec::with_capacity(1 + payload.len());
    sum_input.push(kind);
    sum_input.extend_from_slice(&payload);
    let mut out = Vec::with_capacity(4 + 1 + payload.len() + 8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&payload);
    out.extend_from_slice(&fnv1a64(&sum_input).to_le_bytes());
    out
}

/// fsync the parent directory so a just-created file's directory entry
/// is durable — without this, a crash after creating (and syncing) the
/// WAL can lose the *whole file*, which would silently erase every
/// acknowledged update in it. Errors are surfaced: an unsyncable dir is
/// as fatal to the durability contract as an unsyncable file.
fn fsync_parent(path: &Path) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        File::open(dir)?.sync_all()?;
    }
    Ok(())
}

/// Append one frame and fsync (plus the parent directory when this
/// append created the file). The open-append-sync-close cycle keeps the
/// writer stateless (no long-lived descriptor to invalidate when a DROP
/// deletes the file under a racing verb).
pub fn append(path: &Path, rec: &WalRecord) -> io::Result<()> {
    let created = !path.exists();
    let mut f = OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(&encode_frame(rec))?;
    f.sync_all()?;
    if created {
        fsync_parent(path)?;
    }
    Ok(())
}

/// Truncate the log to empty (compaction: a snapshot now covers every
/// frame) and fsync file + directory entry.
pub fn truncate(path: &Path) -> io::Result<()> {
    let f = File::create(path)?;
    f.sync_all()?;
    fsync_parent(path)
}

/// Truncate and write a first frame in one go (`LOAD` resetting a name).
pub fn reset_with(path: &Path, rec: &WalRecord) -> io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(&encode_frame(rec))?;
    f.sync_all()?;
    fsync_parent(path)
}

/// Parse frames from raw bytes, stopping at the first torn or corrupt
/// frame. Returns the valid prefix and whether a tail was dropped.
pub fn parse_frames(bytes: &[u8]) -> (Vec<WalRecord>, bool) {
    let (records, consumed) = parse_frames_incremental(bytes);
    (records, consumed < bytes.len())
}

/// Incremental variant for live tailing: parse as many complete, valid
/// frames as the bytes hold and report how many bytes they span. Any
/// unconsumed tail is *pending* — with a live writer it is an append
/// still in flight (a partial length prefix, a frame whose checksum
/// bytes have not landed yet); on a quiescent file it is the same torn
/// tail [`parse_frames`] reports. The caller re-polls from `consumed`
/// and decides which it is by whether the file is still growing, so a
/// concurrent reader only ever observes a consistent frame prefix.
pub fn parse_frames_incremental(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        let Some(len_bytes) = bytes.get(at..at + 4) else {
            break;
        };
        let len = u32::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
        if len > MAX_FRAME_PAYLOAD {
            break;
        }
        let frame_end = at + 4 + 1 + len + 8;
        if frame_end > bytes.len() {
            break; // frame runs past EOF: torn or still being appended
        }
        let kind = bytes[at + 4];
        let payload = &bytes[at + 5..at + 5 + len];
        let sum =
            u64::from_le_bytes(bytes[frame_end - 8..frame_end].try_into().unwrap());
        let mut sum_input = Vec::with_capacity(1 + len);
        sum_input.push(kind);
        sum_input.extend_from_slice(payload);
        if fnv1a64(&sum_input) != sum {
            break; // checksum: torn, corrupt, or checksum not yet written
        }
        let Some(rec) = WalRecord::decode(kind, payload) else {
            break;
        };
        records.push(rec);
        at = frame_end;
    }
    (records, at)
}

/// Tail a WAL from a byte offset: parse every complete frame at or past
/// `offset` and return them with the offset to resume from. A missing
/// file is an empty log at the same offset (the writer has not created
/// it yet — or a `DROP` removed it).
pub fn tail_from(path: &Path, offset: u64) -> io::Result<(Vec<WalRecord>, u64)> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            use std::io::Seek;
            f.seek(io::SeekFrom::Start(offset))?;
            f.read_to_end(&mut bytes)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok((Vec::new(), offset)),
        Err(e) => return Err(e),
    }
    let (records, consumed) = parse_frames_incremental(&bytes);
    Ok((records, offset + consumed as u64))
}

/// Read a WAL file; a missing file is an empty log. See [`parse_frames`]
/// for the torn-tail contract.
pub fn read_wal(path: &Path) -> io::Result<(Vec<WalRecord>, bool)> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)?;
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok((Vec::new(), false)),
        Err(e) => return Err(e),
    }
    Ok(parse_frames(&bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd(v: u64) -> WalRecord {
        WalRecord::Update {
            version_after: v,
            batch_wire: format!("add=0:{v}"),
            report_wire: format!("ins=0:{v} del= cols= rows= rejected=0 rebuilt=0"),
        }
    }

    #[test]
    fn frames_roundtrip() {
        let records = vec![
            WalRecord::Load { version_base: 1 << 32 },
            upd((1 << 32) + 1),
            upd((1 << 32) + 2),
            WalRecord::Drop { version: (1 << 32) + 2 },
        ];
        let mut bytes = Vec::new();
        for r in &records {
            bytes.extend_from_slice(&encode_frame(r));
        }
        let (parsed, torn) = parse_frames(&bytes);
        assert!(!torn);
        assert_eq!(parsed, records);
    }

    #[test]
    fn every_truncation_of_the_final_frame_drops_exactly_it() {
        // the crash-consistency kernel: cutting the file anywhere inside
        // the last frame must yield the full prefix and nothing more
        let mut bytes = Vec::new();
        for v in 0..3u64 {
            bytes.extend_from_slice(&encode_frame(&upd(v)));
        }
        let last = encode_frame(&upd(3));
        let prefix_len = bytes.len();
        bytes.extend_from_slice(&last);
        for cut in prefix_len..bytes.len() {
            let (parsed, torn) = parse_frames(&bytes[..cut]);
            assert_eq!(parsed.len(), 3, "cut at {cut}");
            assert!(torn, "cut at {cut} must report a dropped tail");
        }
        let (parsed, torn) = parse_frames(&bytes);
        assert_eq!(parsed.len(), 4);
        assert!(!torn);
    }

    #[test]
    fn corrupt_byte_drops_the_tail_not_the_prefix() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&encode_frame(&upd(0)));
        let second_start = bytes.len();
        bytes.extend_from_slice(&encode_frame(&upd(1)));
        // flip a payload byte in the second frame
        bytes[second_start + 6] ^= 0xFF;
        let (parsed, torn) = parse_frames(&bytes);
        assert_eq!(parsed, vec![upd(0)]);
        assert!(torn);
        // an absurd length prefix is rejected without allocating
        let mut bytes = encode_frame(&upd(0));
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let (parsed, torn) = parse_frames(&bytes);
        assert_eq!(parsed.len(), 1);
        assert!(torn);
    }

    #[test]
    fn incremental_parse_reports_consumed_prefix() {
        let mut bytes = Vec::new();
        for v in 0..3u64 {
            bytes.extend_from_slice(&encode_frame(&upd(v)));
        }
        let whole = bytes.len();
        bytes.extend_from_slice(&encode_frame(&upd(3))[..7]); // in-flight append
        let (recs, consumed) = parse_frames_incremental(&bytes);
        assert_eq!(recs.len(), 3);
        assert_eq!(consumed, whole, "pending tail must not be consumed");
        let (recs, consumed) = parse_frames_incremental(&bytes[..whole]);
        assert_eq!(recs.len(), 3);
        assert_eq!(consumed, whole);
    }

    #[test]
    fn tail_from_resumes_at_returned_offset() {
        let dir = super::super::tests::tempdir("waltail");
        let path = dir.join("g.wal");
        let (recs, off) = tail_from(&path, 0).unwrap();
        assert!(recs.is_empty());
        assert_eq!(off, 0, "missing file stays at the caller's offset");
        append(&path, &upd(1)).unwrap();
        append(&path, &upd(2)).unwrap();
        let (recs, off) = tail_from(&path, 0).unwrap();
        assert_eq!(recs, vec![upd(1), upd(2)]);
        append(&path, &upd(3)).unwrap();
        let (recs, off2) = tail_from(&path, off).unwrap();
        assert_eq!(recs, vec![upd(3)]);
        let (recs, off3) = tail_from(&path, off2).unwrap();
        assert!(recs.is_empty());
        assert_eq!(off3, off2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_append_reset_truncate() {
        let dir = super::super::tests::tempdir("wal");
        let path = dir.join("g.wal");
        assert_eq!(read_wal(&path).unwrap(), (vec![], false), "missing file is empty log");
        append(&path, &WalRecord::Load { version_base: 0 }).unwrap();
        append(&path, &upd(1)).unwrap();
        let (recs, torn) = read_wal(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert!(!torn);
        reset_with(&path, &WalRecord::Load { version_base: 1 << 32 }).unwrap();
        let (recs, _) = read_wal(&path).unwrap();
        assert_eq!(recs, vec![WalRecord::Load { version_base: 1 << 32 }]);
        truncate(&path).unwrap();
        assert_eq!(read_wal(&path).unwrap(), (vec![], false));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
