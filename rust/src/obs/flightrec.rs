//! The flight recorder: a bounded ring of recent event lines that can
//! be turned into an on-disk postmortem three ways — a panic hook, the
//! `DUMP` wire verb, and a once-a-second background flush of
//! `flightrec/latest.jsonl` (so even SIGKILL, which runs no hooks,
//! leaves the last flushed ring behind).
//!
//! Same slot discipline as [`crate::trace::TraceRing`]: an atomic head
//! plus brief per-slot mutexes, never held across I/O. Recording is the
//! only hot-path cost; everything file-shaped happens on dump/flush.

use super::Obs;
use crate::trace::{json_escape, unix_ms};
use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once, Weak};

/// Bounded ring of pre-rendered JSONL event lines.
pub struct FlightRecorder {
    slots: Vec<Mutex<Option<(u64, String)>>>,
    head: AtomicU64,
    /// head value at the last `latest.jsonl` flush (skip no-op flushes)
    flushed: AtomicU64,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Self {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
            flushed: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Lines recorded so far (monotonic, not clamped to capacity).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    pub fn record(&self, line: &str) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        // brief per-slot lock: one String swap, never held across work
        *self.slots[slot].lock().unwrap() = Some((seq, line.to_string()));
    }

    /// The ring's current contents, oldest → newest.
    pub fn snapshot(&self) -> Vec<String> {
        let mut entries: Vec<(u64, String)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            if let Some((seq, line)) = slot.lock().unwrap().as_ref() {
                entries.push((*seq, line.clone()));
            }
        }
        entries.sort_by_key(|(seq, _)| *seq);
        entries.into_iter().map(|(_, l)| l).collect()
    }
}

fn header(reason: &str, events: usize) -> String {
    format!(
        "{{\"schema\":\"bimatch-flightrec/1\",\"reason\":\"{}\",\"ts_ms\":{},\"events\":{}}}",
        json_escape(reason),
        unix_ms(),
        events
    )
}

/// Write a one-shot dump `dump-<reason>-<ts>.jsonl` under `dir`
/// (creating it): a schema header line, then the ring oldest → newest.
pub fn dump_to(ring: &FlightRecorder, dir: &Path, reason: &str) -> io::Result<(PathBuf, usize)> {
    fs::create_dir_all(dir)?;
    let events = ring.snapshot();
    // filename-safe reason; uniqueness from the wall clock + recorded count
    let tag: String =
        reason.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '-' }).collect();
    let path = dir.join(format!("dump-{tag}-{}-{}.jsonl", unix_ms(), ring.recorded()));
    let mut f = fs::File::create(&path)?;
    writeln!(f, "{}", header(reason, events.len()))?;
    for line in &events {
        writeln!(f, "{line}")?;
    }
    f.sync_all()?;
    Ok((path, events.len()))
}

/// Refresh `latest.jsonl` under `dir` via tmp + atomic rename; skipped
/// when nothing was recorded since the previous flush (so an idle
/// server doesn't rewrite the file every tick).
pub fn flush_latest(ring: &FlightRecorder, dir: &Path) -> io::Result<()> {
    let head = ring.recorded();
    if ring.flushed.swap(head, Ordering::Relaxed) == head && dir.join("latest.jsonl").exists() {
        return Ok(());
    }
    fs::create_dir_all(dir)?;
    let events = ring.snapshot();
    let tmp = dir.join("latest.jsonl.tmp");
    {
        let mut f = fs::File::create(&tmp)?;
        writeln!(f, "{}", header("flush", events.len()))?;
        for line in &events {
            writeln!(f, "{line}")?;
        }
        f.sync_all()?;
    }
    fs::rename(&tmp, dir.join("latest.jsonl"))
}

static PANIC_SINKS: Mutex<Vec<Weak<Obs>>> = Mutex::new(Vec::new());
static PANIC_HOOK: Once = Once::new();

/// Register `obs` with the process-wide panic hook: a panic anywhere
/// records a `panic` event and dumps every registered recorder that has
/// a data dir. The hook chains the previous one (the backtrace still
/// prints), installs once, and holds only weak handles — a server torn
/// down by tests stops being dumped.
pub fn register_panic_dump(obs: &Arc<Obs>) {
    {
        let mut sinks = PANIC_SINKS.lock().unwrap();
        sinks.retain(|w| w.strong_count() > 0);
        sinks.push(Arc::downgrade(obs));
    }
    PANIC_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            // a poisoned registry must not abort inside the hook
            if let Ok(sinks) = PANIC_SINKS.lock() {
                for obs in sinks.iter().filter_map(Weak::upgrade) {
                    obs.event(super::Level::Error, "panic")
                        .field("message", &info.to_string())
                        .emit();
                    if obs.data_dir().is_some() {
                        let _ = obs.dump("panic");
                    }
                }
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Level;

    fn tempdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_flightrec_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn ring_keeps_the_newest_capacity_lines_in_order() {
        let ring = FlightRecorder::new(4);
        for i in 0..10 {
            ring.record(&format!("{{\"n\":{i}}}"));
        }
        assert_eq!(ring.recorded(), 10);
        let snap = ring.snapshot();
        assert_eq!(snap, vec!["{\"n\":6}", "{\"n\":7}", "{\"n\":8}", "{\"n\":9}"]);
    }

    #[test]
    fn dump_writes_header_plus_events() {
        let dir = tempdir("dump");
        let ring = FlightRecorder::new(8);
        ring.record("{\"event\":\"a\"}");
        ring.record("{\"event\":\"b\"}");
        let (path, n) = dump_to(&ring, &dir, "unit test").unwrap();
        assert_eq!(n, 2);
        let text = fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"schema\":\"bimatch-flightrec/1\""), "{}", lines[0]);
        assert!(lines[0].contains("\"reason\":\"unit test\""));
        assert!(lines[0].contains("\"events\":2"));
        assert_eq!(lines[1], "{\"event\":\"a\"}");
        assert_eq!(lines[2], "{\"event\":\"b\"}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_latest_is_atomic_and_skips_when_clean() {
        let dir = tempdir("flush");
        let ring = FlightRecorder::new(8);
        ring.record("{\"event\":\"x\"}");
        flush_latest(&ring, &dir).unwrap();
        let latest = dir.join("latest.jsonl");
        let first = fs::read_to_string(&latest).unwrap();
        assert!(first.lines().count() == 2 && first.contains("\"x\""));
        let mtime = fs::metadata(&latest).unwrap().modified().unwrap();
        // nothing recorded since: the file is left untouched
        flush_latest(&ring, &dir).unwrap();
        assert_eq!(fs::metadata(&latest).unwrap().modified().unwrap(), mtime);
        ring.record("{\"event\":\"y\"}");
        flush_latest(&ring, &dir).unwrap();
        assert!(fs::read_to_string(&latest).unwrap().contains("\"y\""));
        assert!(!dir.join("latest.jsonl.tmp").exists(), "tmp renamed away");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn obs_dump_lands_under_flightrec() {
        let dir = tempdir("obsdump");
        let obs = Obs::open(Level::Info.sev(), Some(dir.clone()), 8).unwrap();
        obs.capture_sink();
        obs.event(Level::Info, "hello").emit();
        let (path, n) = obs.dump("verb").unwrap();
        assert_eq!(n, 1);
        assert!(path.starts_with(dir.join("flightrec")));
        assert!(fs::read_to_string(&path).unwrap().contains("\"hello\""));
        assert!(
            Obs::in_memory(Level::Info.sev(), 4).dump("x").is_err(),
            "dumps need a data dir"
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
