//! Fleet-level observability: the structured event log and the
//! always-on flight recorder.
//!
//! PR 9's span tracer answers "where did *this job's* time go"; this
//! layer answers "what has *this process* been doing" — the questions an
//! operator asks a fleet. Two halves share one [`Obs`] handle:
//!
//! * **Structured event log** — every server-lifecycle event
//!   (connection accepted/dropped, drain, eviction, recovery,
//!   promotion/fencing, follower connect/disconnect, WAL compaction,
//!   slow request, panic) is one JSONL object
//!   (`{"ts_ms":…,"level":"…","event":"…",…}`) written to stderr and,
//!   when the server has a data dir, appended to
//!   `<data-dir>/events.jsonl`. Levels follow the usual ladder
//!   (`debug < info < warn < error`); the sink threshold comes from
//!   `serve --log-level` or the `BIMATCH_LOG` env var (`off` silences
//!   the sinks entirely). Each event kind is token-bucketed
//!   ([`RATE_LIMIT_PER_SEC`] per second) so a misbehaving client
//!   cannot turn the log into the bottleneck — suppressed counts are
//!   reported when the window rolls over, never silently dropped.
//! * **Flight recorder** ([`flightrec`]) — a bounded ring that records
//!   *every* event line regardless of level or rate limit (the ring
//!   write is the only cost), plus a one-line span summary per job.
//!   The ring is dumped to `<data-dir>/flightrec/` by a panic hook, on
//!   demand via the `DUMP` wire verb, and once a second by a background
//!   flusher (`latest.jsonl`, tmp+rename) — so even a SIGKILL'd server
//!   leaves a parseable postmortem of its last moments.
//!
//! Everything is hand-rolled JSON (serde is unavailable offline),
//! escaping through [`crate::trace::json_escape`] — the same encoder
//! the trace layer's `TRACE` verb uses.

pub mod flightrec;

pub use flightrec::FlightRecorder;

use crate::sanitize::lockorder::{self, LockClass};
use crate::trace::{json_escape, unix_ms};
use std::collections::HashMap;
use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Severity of one event. Ordered: `Debug < Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug,
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn name(&self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    fn sev(self) -> u8 {
        match self {
            Level::Debug => 0,
            Level::Info => 1,
            Level::Warn => 2,
            Level::Error => 3,
        }
    }
}

/// Sink threshold: events below it skip the sinks (never the ring).
/// `0..=3` map to [`Level`]; [`FILTER_OFF`] silences the sinks.
pub const FILTER_OFF: u8 = 4;

/// Parse a `--log-level` / `BIMATCH_LOG` value.
pub fn parse_filter(s: &str) -> Option<u8> {
    match s {
        "debug" => Some(Level::Debug.sev()),
        "info" => Some(Level::Info.sev()),
        "warn" => Some(Level::Warn.sev()),
        "error" => Some(Level::Error.sev()),
        "off" => Some(FILTER_OFF),
        _ => None,
    }
}

pub fn filter_name(f: u8) -> &'static str {
    match f {
        0 => "debug",
        1 => "info",
        2 => "warn",
        3 => "error",
        _ => "off",
    }
}

/// The default sink threshold: `BIMATCH_LOG` when set and valid,
/// otherwise `info`.
pub fn filter_from_env() -> u8 {
    std::env::var("BIMATCH_LOG")
        .ok()
        .and_then(|v| parse_filter(&v))
        .unwrap_or_else(|| Level::Info.sev())
}

/// Per-kind sink budget: at most this many lines of one event kind
/// reach stderr/the file per second. The ring is never limited.
pub const RATE_LIMIT_PER_SEC: u32 = 50;

struct Window {
    start: Instant,
    emitted: u32,
    suppressed: u64,
}

struct SinkState {
    /// `<data-dir>/events.jsonl`, append mode; `None` without a data dir
    file: Option<fs::File>,
    /// per-kind rate-limit windows
    windows: HashMap<&'static str, Window>,
    /// tests: capture sink lines instead of writing stderr
    capture: Option<Vec<String>>,
}

/// The process-wide observability handle: event log sinks + flight
/// recorder ring. Cheap to clone via `Arc`; every component (server
/// accept loop, executor, replication tailer) shares one.
pub struct Obs {
    filter: AtomicU8,
    sink: Mutex<SinkState>,
    ring: FlightRecorder,
    data_dir: Option<PathBuf>,
}

impl Obs {
    /// Open the full handle: sink threshold `filter`, a ring of
    /// `ring_capacity` lines, and — when `data_dir` is set — the
    /// `events.jsonl` append sink plus the `flightrec/` dump target.
    pub fn open(
        filter: u8,
        data_dir: Option<PathBuf>,
        ring_capacity: usize,
    ) -> io::Result<Arc<Self>> {
        let file = match &data_dir {
            Some(dir) => {
                fs::create_dir_all(dir)?;
                Some(fs::OpenOptions::new().create(true).append(true).open(dir.join("events.jsonl"))?)
            }
            None => None,
        };
        Ok(Arc::new(Self {
            filter: AtomicU8::new(filter),
            sink: Mutex::new(SinkState { file, windows: HashMap::new(), capture: None }),
            ring: FlightRecorder::new(ring_capacity),
            data_dir,
        }))
    }

    /// A sink-less handle (ring only) for embedded/test use.
    pub fn in_memory(filter: u8, ring_capacity: usize) -> Arc<Self> {
        Self::open(filter, None, ring_capacity).expect("no I/O without a data dir")
    }

    /// Divert sink output into an in-memory buffer (tests assert on
    /// exactly what an operator would have seen on stderr).
    pub fn capture_sink(&self) {
        lockorder::lock(LockClass::Obs, &self.sink).capture = Some(Vec::new());
    }

    /// Drain the capture buffer set up by [`Obs::capture_sink`].
    pub fn captured(&self) -> Vec<String> {
        lockorder::lock(LockClass::Obs, &self.sink)
            .capture
            .as_mut()
            .map(std::mem::take)
            .unwrap_or_default()
    }

    pub fn data_dir(&self) -> Option<&Path> {
        self.data_dir.as_deref()
    }

    pub fn filter(&self) -> u8 {
        self.filter.load(Ordering::Relaxed)
    }

    pub fn set_filter(&self, f: u8) {
        self.filter.store(f, Ordering::Relaxed);
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.ring
    }

    /// Start one event. Finish with [`EventBuilder::emit`]:
    ///
    /// ```ignore
    /// obs.event(Level::Info, "graph_evicted")
    ///     .field("graph", name)
    ///     .field_u64("version", v)
    ///     .emit();
    /// ```
    pub fn event(&self, level: Level, kind: &'static str) -> EventBuilder<'_> {
        EventBuilder { obs: self, level, kind, fields: String::new() }
    }

    fn submit(&self, level: Level, kind: &'static str, fields: &str) {
        let line = format!(
            "{{\"ts_ms\":{},\"level\":\"{}\",\"event\":\"{}\"{}}}",
            unix_ms(),
            level.name(),
            kind,
            fields
        );
        // the ring records everything — postmortems must not depend on
        // the sink threshold or the rate limiter
        self.ring.record(&line);
        if level.sev() < self.filter.load(Ordering::Relaxed) {
            return;
        }
        let mut st = lockorder::lock(LockClass::Obs, &self.sink);
        let now = Instant::now();
        let w = st
            .windows
            .entry(kind)
            .or_insert(Window { start: now, emitted: 0, suppressed: 0 });
        let mut rollover = None;
        if now.duration_since(w.start).as_secs() >= 1 {
            if w.suppressed > 0 {
                rollover = Some(w.suppressed);
            }
            *w = Window { start: now, emitted: 0, suppressed: 0 };
        }
        if w.emitted >= RATE_LIMIT_PER_SEC {
            w.suppressed += 1;
            return;
        }
        w.emitted += 1;
        if let Some(count) = rollover {
            let summary = format!(
                "{{\"ts_ms\":{},\"level\":\"warn\",\"event\":\"log_suppressed\",\
                 \"of\":\"{kind}\",\"count\":{count}}}",
                unix_ms()
            );
            write_sinks(&mut st, &summary);
        }
        write_sinks(&mut st, &line);
    }

    /// Write a flight-recorder dump to
    /// `<data-dir>/flightrec/dump-<reason>-<ts>.jsonl` (header line,
    /// then the ring oldest→newest). Errors without a data dir.
    pub fn dump(&self, reason: &str) -> io::Result<(PathBuf, usize)> {
        let dir = self.data_dir.as_ref().ok_or_else(|| {
            io::Error::new(io::ErrorKind::NotFound, "flight recorder dumps require a data dir")
        })?;
        flightrec::dump_to(&self.ring, &dir.join("flightrec"), reason)
    }

    /// Refresh `<data-dir>/flightrec/latest.jsonl` (tmp + atomic
    /// rename): the black-box artifact a SIGKILL leaves behind. No-op
    /// without a data dir or when nothing was recorded since last time.
    pub fn flush_latest(&self) -> io::Result<()> {
        let Some(dir) = &self.data_dir else { return Ok(()) };
        flightrec::flush_latest(&self.ring, &dir.join("flightrec"))
    }
}

fn write_sinks(st: &mut SinkState, line: &str) {
    if let Some(buf) = &mut st.capture {
        buf.push(line.to_string());
    } else {
        let mut err = io::stderr().lock();
        let _ = writeln!(err, "{line}");
    }
    if let Some(f) = &mut st.file {
        let _ = writeln!(f, "{line}");
    }
}

/// One event under construction; fields append in call order.
pub struct EventBuilder<'a> {
    obs: &'a Obs,
    level: Level,
    kind: &'static str,
    fields: String,
}

impl EventBuilder<'_> {
    pub fn field(mut self, key: &str, value: &str) -> Self {
        self.fields.push_str(&format!(",\"{key}\":\"{}\"", json_escape(value)));
        self
    }

    pub fn field_u64(mut self, key: &str, value: u64) -> Self {
        self.fields.push_str(&format!(",\"{key}\":{value}"));
        self
    }

    pub fn field_f64(mut self, key: &str, value: f64) -> Self {
        if value.is_finite() {
            self.fields.push_str(&format!(",\"{key}\":{value:.3}"));
        } else {
            self.fields.push_str(&format!(",\"{key}\":null"));
        }
        self
    }

    pub fn field_bool(mut self, key: &str, value: bool) -> Self {
        self.fields.push_str(&format!(",\"{key}\":{value}"));
        self
    }

    pub fn emit(self) {
        self.obs.submit(self.level, self.kind, &self.fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_parses_and_orders() {
        assert_eq!(parse_filter("debug"), Some(0));
        assert_eq!(parse_filter("info"), Some(1));
        assert_eq!(parse_filter("warn"), Some(2));
        assert_eq!(parse_filter("error"), Some(3));
        assert_eq!(parse_filter("off"), Some(FILTER_OFF));
        assert_eq!(parse_filter("verbose"), None);
        assert!(Level::Debug < Level::Error);
        assert_eq!(filter_name(FILTER_OFF), "off");
    }

    #[test]
    fn events_render_as_one_json_object_per_line() {
        let obs = Obs::in_memory(Level::Debug.sev(), 8);
        obs.capture_sink();
        obs.event(Level::Info, "conn_accept")
            .field("peer", "127.0.0.1:5\"quoted\"")
            .field_u64("conn", 3)
            .field_f64("total_ms", 1.25)
            .field_bool("ok", true)
            .emit();
        let lines = obs.captured();
        assert_eq!(lines.len(), 1);
        let l = &lines[0];
        assert!(l.starts_with("{\"ts_ms\":"), "{l}");
        assert!(l.contains("\"event\":\"conn_accept\""), "{l}");
        assert!(l.contains("\"peer\":\"127.0.0.1:5\\\"quoted\\\"\""), "{l}");
        assert!(l.contains("\"conn\":3"), "{l}");
        assert!(l.contains("\"total_ms\":1.250"), "{l}");
        assert!(l.contains("\"ok\":true"), "{l}");
        assert!(l.ends_with('}'), "{l}");
        assert!(!l.contains('\n'));
    }

    #[test]
    fn sink_threshold_filters_but_ring_records_everything() {
        let obs = Obs::in_memory(Level::Warn.sev(), 8);
        obs.capture_sink();
        obs.event(Level::Debug, "noise").emit();
        obs.event(Level::Info, "noise").emit();
        obs.event(Level::Error, "loud").emit();
        let lines = obs.captured();
        assert_eq!(lines.len(), 1, "{lines:?}");
        assert!(lines[0].contains("\"loud\""));
        assert_eq!(obs.recorder().recorded(), 3, "the ring sees every level");
        let ring = obs.recorder().snapshot();
        assert!(ring[0].contains("\"noise\"") && ring[2].contains("\"loud\""));
    }

    #[test]
    fn off_silences_sinks_entirely() {
        let obs = Obs::in_memory(FILTER_OFF, 4);
        obs.capture_sink();
        obs.event(Level::Error, "anything").emit();
        assert!(obs.captured().is_empty());
        assert_eq!(obs.recorder().recorded(), 1);
    }

    #[test]
    fn per_kind_rate_limit_caps_the_sink_not_the_ring() {
        let obs = Obs::in_memory(Level::Debug.sev(), 512);
        obs.capture_sink();
        for _ in 0..(RATE_LIMIT_PER_SEC + 25) {
            obs.event(Level::Info, "chatty").emit();
        }
        // a different kind has its own budget
        obs.event(Level::Info, "quiet").emit();
        let lines = obs.captured();
        let chatty = lines.iter().filter(|l| l.contains("\"chatty\"")).count();
        assert_eq!(chatty, RATE_LIMIT_PER_SEC as usize);
        assert_eq!(lines.iter().filter(|l| l.contains("\"quiet\"")).count(), 1);
        assert_eq!(obs.recorder().recorded() as u32, RATE_LIMIT_PER_SEC + 26);
    }

    #[test]
    fn file_sink_appends_jsonl() {
        let dir = std::env::temp_dir().join(format!("bimatch_obs_file_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = Obs::open(Level::Info.sev(), Some(dir.clone()), 8).unwrap();
        obs.capture_sink();
        obs.event(Level::Info, "first").field_u64("n", 1).emit();
        obs.event(Level::Warn, "second").emit();
        let text = std::fs::read_to_string(dir.join("events.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"first\"") && lines[1].contains("\"second\""));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
