//! Block-triangular form (BTF) of a sparse square matrix — the paper's
//! motivating application (§1): "bipartite matching algorithms are used to
//! see if the associated coefficient matrix is reducible; if so,
//! substantial savings in computational requirements can be achieved."
//!
//! Pipeline: maximum transversal (any matcher from the registry) puts
//! nonzeros on the diagonal; Tarjan's SCC over the matched digraph yields
//! the diagonal blocks (the fine Dulmage–Mendelsohn decomposition for the
//! structurally-nonsingular case).

use crate::graph::csr::BipartiteCsr;
use crate::matching::Matching;

/// Result of the BTF analysis.
#[derive(Debug, Clone)]
pub struct Btf {
    /// diagonal block sizes in topological order of the condensation
    pub block_sizes: Vec<usize>,
    /// column → block id
    pub block_of: Vec<u32>,
    /// |maximum transversal| (== n iff structurally nonsingular)
    pub transversal: usize,
}

impl Btf {
    pub fn n_blocks(&self) -> usize {
        self.block_sizes.len()
    }

    pub fn is_reducible(&self) -> bool {
        self.block_sizes.len() > 1
    }

    /// Dense-LU cost-model savings of factoring per block: n³ / Σ bᵢ³.
    pub fn lu_savings(&self, n: usize) -> f64 {
        let full = (n as f64).powi(3);
        let btf: f64 = self.block_sizes.iter().map(|&b| (b as f64).powi(3)).sum();
        if btf == 0.0 {
            1.0
        } else {
            full / btf
        }
    }
}

/// Compute the BTF of the (square, structurally nonsingular) matrix whose
/// bipartite graph is `g`, given a *maximum* matching. Returns None when
/// the transversal is deficient (matrix structurally singular — no BTF).
pub fn btf(g: &BipartiteCsr, m: &Matching) -> Option<Btf> {
    if g.nr != g.nc {
        return None;
    }
    let n = g.nc;
    let card = m.cardinality();
    if card != n {
        return None;
    }

    // Tarjan SCC, iterative. Digraph on columns: u → v iff the row matched
    // to u has a nonzero in column v.
    const UNSEEN: u32 = u32::MAX;
    let mut index = vec![UNSEEN; n];
    let mut low = vec![0u32; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<u32> = Vec::new();
    let mut call: Vec<(u32, u32)> = Vec::new();
    let mut next_index = 0u32;
    let mut block_sizes = Vec::new();
    let mut block_of = vec![0u32; n];

    for root in 0..n {
        if index[root] != UNSEEN {
            continue;
        }
        call.push((root as u32, 0));
        while let Some(&mut (vu, ref mut ci)) = call.last_mut() {
            let v = vu as usize;
            if *ci == 0 {
                index[v] = next_index;
                low[v] = next_index;
                next_index += 1;
                stack.push(vu);
                on_stack[v] = true;
            }
            let r = m.cmatch[v] as usize;
            let children = g.row_neighbors(r);
            let mut advanced = false;
            while (*ci as usize) < children.len() {
                let w = children[*ci as usize] as usize;
                *ci += 1;
                if w == v {
                    continue;
                }
                if index[w] == UNSEEN {
                    call.push((w as u32, 0));
                    advanced = true;
                    break;
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            }
            if advanced {
                continue;
            }
            if low[v] == index[v] {
                let bid = block_sizes.len() as u32;
                let mut size = 0usize;
                loop {
                    let w = stack.pop().unwrap();
                    on_stack[w as usize] = false;
                    block_of[w as usize] = bid;
                    size += 1;
                    if w == vu {
                        break;
                    }
                }
                block_sizes.push(size);
            }
            call.pop();
            if let Some(&mut (p, _)) = call.last_mut() {
                let p = p as usize;
                low[p] = low[p].min(low[v]);
            }
        }
    }
    Some(Btf { block_sizes, block_of, transversal: card })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::seq::Hk;
    use crate::MatchingAlgorithm;

    fn max_matching(g: &BipartiteCsr) -> Matching {
        Hk.run_detached(g, Matching::empty(g.nr, g.nc)).matching
    }

    #[test]
    fn diagonal_matrix_fully_reducible() {
        let g = from_edges(4, 4, &[(0, 0), (1, 1), (2, 2), (3, 3)]);
        let b = btf(&g, &max_matching(&g)).unwrap();
        assert_eq!(b.n_blocks(), 4);
        assert!(b.is_reducible());
        assert!(b.lu_savings(4) > 1.0);
        assert_eq!(b.block_sizes, vec![1, 1, 1, 1]);
    }

    #[test]
    fn full_cycle_irreducible() {
        // circulant: A[i][i] and A[i][(i+1)%n] — one big SCC
        let n = 5;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            edges.push((i, i));
            edges.push((i, (i + 1) % n as u32));
        }
        let g = from_edges(n, n, &edges);
        let b = btf(&g, &max_matching(&g)).unwrap();
        assert_eq!(b.n_blocks(), 1);
        assert!(!b.is_reducible());
        assert_eq!(b.block_sizes, vec![n]);
    }

    #[test]
    fn upper_triangular_block_structure() {
        // two 2x2 dense blocks + coupling block0 -> block1 only
        let edges = [
            (0, 0), (0, 1), (1, 0), (1, 1), // block {0,1}
            (2, 2), (2, 3), (3, 2), (3, 3), // block {2,3}
            (0, 2), // coupling (upper)
        ];
        let g = from_edges(4, 4, &edges);
        let b = btf(&g, &max_matching(&g)).unwrap();
        assert_eq!(b.n_blocks(), 2);
        let mut sizes = b.block_sizes.clone();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![2, 2]);
        // columns within the same dense block share a block id
        assert_eq!(b.block_of[0], b.block_of[1]);
        assert_eq!(b.block_of[2], b.block_of[3]);
        assert_ne!(b.block_of[0], b.block_of[2]);
    }

    #[test]
    fn singular_matrix_rejected() {
        // column 1 empty -> deficient transversal
        let g = from_edges(2, 2, &[(0, 0), (1, 0)]);
        assert!(btf(&g, &max_matching(&g)).is_none());
        // rectangular rejected
        let r = from_edges(2, 3, &[(0, 0), (1, 1), (0, 2)]);
        assert!(btf(&r, &max_matching(&r)).is_none());
    }

    #[test]
    fn block_sizes_sum_to_n() {
        let g = crate::graph::gen::banded(300, 6, 0.5, 3);
        if let Some(b) = btf(&g, &max_matching(&g)) {
            assert_eq!(b.block_sizes.iter().sum::<usize>(), 300);
            assert_eq!(b.block_of.len(), 300);
        }
    }
}
