//! Downstream applications of maximum bipartite matching — the uses the
//! paper's introduction motivates. Currently: block-triangular form for
//! sparse direct solvers ([`btf`]).

pub mod btf;

pub use btf::{btf, Btf};
