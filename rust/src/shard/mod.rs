//! Sharded multi-device execution: one matching run partitioned
//! column-wise across K simulated devices with a modeled interconnect.
//!
//! ## Execution model
//!
//! * [`partition::ColPartition`] splits the CSR's columns into K
//!   contiguous, edge-balanced ranges; row arrays (`rmatch`,
//!   `predecessor`) are replicated on every device.
//! * [`driver::ShardedGpuMatcher`] runs the paper's phase loop
//!   bulk-synchronously: within each BFS level every shard launches the
//!   level kernel over its own columns (full-scan via
//!   `gpu::kernels::gpubfs_cols` / `gpubfs_wr_cols`, or its local
//!   frontier worklist under `FrontierMode::Compacted`), then an
//!   explicit *frontier exchange* routes every claimed column to its
//!   owning shard and a barrier aligns the per-shard clocks.
//! * `gpu::device::ShardClocks` carries one `DeviceClock` per shard plus
//!   the interconnect tallies. Exchange traffic is priced like the rest
//!   of the cost model — `EXCHANGE_MSG_COST` per source→dest batch,
//!   `EXCHANGE_WORD_COST` per 32-bit word, `EXCHANGE_WORDS_PER_ITEM`
//!   words per routed `(row, column)` pair — and the run's bill is
//!   `ShardClocks::makespan`: BSP makespan in the parallel view (max
//!   shard clock, exchange bottlenecks included), total work plus the
//!   full serial exchange bill in the serial view.
//! * Phases with no parallelism across columns (INITBFSARRAY, ALTERNATE,
//!   FIXMATCHING, endpoint selection) run *replicated*: every device
//!   performs them over its replicated arrays, so the makespan pays one
//!   copy and the work view pays K.
//!
//! `shards == 1` degenerates to the unsharded `gpu::driver` bill
//! exactly; the cardinality is identical to unsharded execution for
//! every K (the host executes shards sequentially — one legal
//! serialization of the device race, and the matching cardinality is
//! schedule-independent).
//!
//! The partition/exchange shape follows the distributed-memory matching
//! literature — notably Birn, Osipov, Sanders, Schulz, Sitchinava,
//! *"Efficient Parallel and External Matching"* (Euro-Par 2013), whose
//! partitioned graph + owner-routed border-vertex exchange this module
//! adapts to the paper's push-style BFS phases — rather than any shared
//! memory decomposition: the interconnect is charged explicitly so the
//! benches can quantify when sharding pays and when the exchange tax
//! eats the win (`benches/bench_shard.rs`).
//!
//! Wire syntax: `shard{K}:gpu:{variant}` (e.g.
//! `shard4:gpu:APFB-GPUBFS-WR-CT-FC`), registered for K ∈ {2, 4, 8} and
//! parseable for any K ≥ 1.

pub mod driver;
pub mod partition;

pub use driver::ShardedGpuMatcher;
pub use partition::ColPartition;
