//! Column-wise CSR partitioning for sharded execution.
//!
//! A [`ColPartition`] assigns every column of a [`BipartiteCsr`] to
//! exactly one of `K` simulated devices as a *contiguous range*,
//! balanced by edge count (each shard's BFS sweep cost is proportional
//! to the edges it scans, not the columns it owns). Contiguity keeps
//! ownership lookups a binary search over `K+1` cut points and lets the
//! per-shard full-scan kernels (`gpu::kernels::gpubfs_cols`) launch over
//! a plain range — no ownership indirection on the hot path.
//!
//! Rows are replicated: every shard can read any row's `rmatch` /
//! `predecessor` slot, but a BFS step that *claims* a column owned by
//! another shard must route the `(row, column)` pair over the modeled
//! interconnect (see `gpu::device::EXCHANGE_WORDS_PER_ITEM`). The rows
//! whose neighbor columns span more than one shard — the *boundary
//! rows* — are the only possible sources of such traffic, which is what
//! [`ColPartition::boundary_edge_count`] quantifies.

use crate::graph::csr::BipartiteCsr;
use std::ops::Range;

/// A contiguous, edge-balanced partition of the columns of one graph
/// across `K` shards. Shard `s` owns columns `cuts[s] .. cuts[s+1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColPartition {
    /// `K + 1` monotone cut points; `cuts[0] == 0`, `cuts[K] == nc`.
    cuts: Vec<u32>,
}

impl ColPartition {
    /// Partition `g`'s columns into `shards` contiguous ranges with
    /// (approximately) equal edge counts, using the CSR column offsets
    /// (`cxadj`) as the prefix-sum oracle: the cut for shard boundary
    /// `s` is the first column whose edge prefix reaches `s/K` of the
    /// total. Degenerate inputs are handled: `shards == 0` is clamped
    /// to 1, an edgeless graph falls back to column-count balance, and
    /// graphs with fewer columns than shards leave the tail shards
    /// empty (their ranges are valid and zero-length).
    pub fn new(g: &BipartiteCsr, shards: usize) -> Self {
        let k = shards.max(1);
        let nc = g.nc;
        let total = g.n_edges() as u64;
        let mut cuts = Vec::with_capacity(k + 1);
        cuts.push(0u32);
        for s in 1..k {
            let cut = if total == 0 {
                // edgeless: balance by column count
                (nc * s / k) as u32
            } else {
                let target = total * s as u64 / k as u64;
                // first column whose prefix reaches the target share
                g.cxadj.partition_point(|&x| (x as u64) < target) as u32
            };
            // monotone: never cut before the previous shard's end
            let prev = *cuts.last().unwrap();
            cuts.push(cut.max(prev).min(nc as u32));
        }
        cuts.push(nc as u32);
        Self { cuts }
    }

    /// Number of shards (always >= 1).
    pub fn shards(&self) -> usize {
        self.cuts.len() - 1
    }

    /// The contiguous column range shard `s` owns (possibly empty).
    pub fn range(&self, s: usize) -> Range<usize> {
        self.cuts[s] as usize..self.cuts[s + 1] as usize
    }

    /// The shard owning column `c`. `c` must be `< nc`.
    pub fn owner_of(&self, c: usize) -> usize {
        debug_assert!((c as u32) < *self.cuts.last().unwrap(), "column out of range");
        // cuts[1..] is sorted; the owner is the first boundary > c
        self.cuts[1..].partition_point(|&cut| cut <= c as u32)
    }

    /// Number of edges incident to *boundary rows* — rows whose neighbor
    /// columns span at least two shards. Because ranges are contiguous,
    /// a row is interior iff its minimum and maximum neighbor columns
    /// share an owner. Every cross-shard item the frontier exchange
    /// routes originates at a boundary row (the claimed column is the
    /// row's match, which is one of its neighbors), so per phase the
    /// routed item count is bounded by the number of boundary rows,
    /// itself at most this edge count.
    pub fn boundary_edge_count(&self, g: &BipartiteCsr) -> u64 {
        let mut edges = 0u64;
        for r in 0..g.nr {
            let neigh = g.row_neighbors(r);
            if neigh.is_empty() {
                continue;
            }
            let mut lo = neigh[0];
            let mut hi = neigh[0];
            for &c in &neigh[1..] {
                lo = lo.min(c);
                hi = hi.max(c);
            }
            if self.owner_of(lo as usize) != self.owner_of(hi as usize) {
                edges += neigh.len() as u64;
            }
        }
        edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::graph::gen::Family;

    #[test]
    fn every_column_owned_by_exactly_one_shard() {
        for fam in Family::ALL {
            let g = fam.generate(300, 5);
            for k in [1usize, 2, 3, 4, 8] {
                let p = ColPartition::new(&g, k);
                assert_eq!(p.shards(), k);
                // ranges tile [0, nc): disjoint and covering
                let mut covered = 0usize;
                for s in 0..k {
                    let r = p.range(s);
                    assert_eq!(r.start, covered, "ranges must tile contiguously");
                    covered = r.end;
                    for c in r.clone() {
                        assert_eq!(p.owner_of(c), s, "owner_of must agree with range()");
                    }
                }
                assert_eq!(covered, g.nc, "{} k={k}: ranges must cover all columns", fam.name());
            }
        }
    }

    #[test]
    fn edge_balance_within_tolerance() {
        // each shard's edge load must stay within 2x of the ideal share
        // plus one max-degree column (cuts are quantized to columns)
        for fam in [Family::Uniform, Family::Road, Family::Kron] {
            let g = fam.generate(2000, 9);
            let total = g.n_edges();
            for k in [2usize, 4, 8] {
                let p = ColPartition::new(&g, k);
                let slack = total / k + g.max_col_degree();
                for s in 0..k {
                    let load: usize = p.range(s).map(|c| g.col_degree(c)).sum();
                    assert!(
                        load <= total / k + slack,
                        "{} k={k} shard {s}: load {load} vs ideal {}",
                        fam.name(),
                        total / k
                    );
                }
            }
        }
    }

    #[test]
    fn single_shard_owns_everything_and_has_no_boundary() {
        let g = Family::Banded.generate(400, 3);
        let p = ColPartition::new(&g, 1);
        assert_eq!(p.range(0), 0..g.nc);
        assert_eq!(p.boundary_edge_count(&g), 0, "K=1 has no shard boundaries");
    }

    #[test]
    fn boundary_edges_counted_exactly_on_a_known_graph() {
        // 4 columns, rows: r0 -> {c0, c1} (interior if same owner),
        // r1 -> {c1, c2} (spans the K=2 cut), r2 -> {c3}
        let g = from_edges(3, 4, &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 3)]);
        let p = ColPartition::new(&g, 2);
        // 5 edges, cut lands at column 2: shard 0 = {c0, c1}, shard 1 = {c2, c3}
        assert_eq!(p.range(0), 0..2);
        assert_eq!(p.range(1), 2..4);
        // r1's neighbors {c1, c2} span both shards: its 2 edges are boundary
        assert_eq!(p.boundary_edge_count(&g), 2);
    }

    #[test]
    fn more_shards_than_columns_leaves_empty_tails() {
        let g = from_edges(2, 3, &[(0, 0), (1, 1), (1, 2)]);
        let p = ColPartition::new(&g, 8);
        assert_eq!(p.shards(), 8);
        let covered: usize = (0..8).map(|s| p.range(s).len()).sum();
        assert_eq!(covered, 3);
        for c in 0..3 {
            let o = p.owner_of(c);
            assert!(p.range(o).contains(&c));
        }
    }

    #[test]
    fn edgeless_graph_balances_by_columns() {
        let g = from_edges(4, 8, &[]);
        let p = ColPartition::new(&g, 4);
        for s in 0..4 {
            assert_eq!(p.range(s).len(), 2);
        }
    }
}
