//! The sharded execution driver: the paper's APFB/APsB phase loop run
//! shard-parallel across K simulated devices (see the module docs of
//! [`crate::shard`] for the execution model and its cost accounting).
//!
//! Shards execute sequentially on the host, in shard order within each
//! BFS level — one legal serialization of the K-device race, exactly the
//! argument `gpu::driver` makes for its host-parallel mode. The matching
//! cardinality is schedule-independent (FIXMATCHING plus the safety net
//! absorb any interleaving), so sharded ≡ unsharded cardinality for
//! every shard count — property-tested in `rust/tests/shard.rs`.

use crate::gpu::config::{ApDriver, BfsKernel, FrontierMode, GpuConfig};
use crate::gpu::device::{
    charge_frontier_scan, charge_uniform_scan, DeviceClock, ShardClocks, EXCHANGE_WORDS_PER_ITEM,
};
use crate::gpu::driver::augment_one_sequential;
use crate::gpu::kernels::{
    alternate, fixmatching, gpubfs_cols, gpubfs_frontier, gpubfs_wr_cols, gpubfs_wr_frontier,
    init_bfs_array, init_bfs_array_frontier, wr_chosen_endpoints_from, GpuState, LaunchCfg, L0,
};
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{MatchingAlgorithm, RunCtx, RunOutcome, RunResult};
use crate::matching::Matching;

use super::partition::ColPartition;

/// One of the paper's GPU variants executed across `shards` simulated
/// devices. `shards == 1` degenerates to the unsharded phase loop (and
/// bills the same modeled cycles); the wire name is
/// `shard{K}:gpu:{variant}`.
#[derive(Debug, Clone, Copy)]
pub struct ShardedGpuMatcher {
    pub inner: GpuConfig,
    pub shards: usize,
}

impl ShardedGpuMatcher {
    pub fn new(inner: GpuConfig, shards: usize) -> Self {
        Self { inner, shards: shards.max(1) }
    }

    /// Run and also return the combined device clock
    /// ([`ShardClocks::makespan`]: total work in `cycles`, BSP makespan in
    /// `parallel_cycles`).
    pub fn run_with_clock(
        &self,
        g: &BipartiteCsr,
        init: Matching,
        ctx: &mut RunCtx,
    ) -> (RunResult, DeviceClock) {
        let k = self.shards.max(1);
        let part = ColPartition::new(g, k);
        // par_threads stays 1: under sharding the shards themselves are
        // the parallelism axis, and each shard's kernels run serially on
        // its own modeled device.
        let cfg = LaunchCfg {
            mapping: self.inner.mapping,
            order: self.inner.write_order,
            seed: self.inner.seed,
            par_threads: 1,
        };
        let with_root = self.inner.kernel == BfsKernel::GpuBfsWr;
        let improved_wr = with_root && self.inner.driver == ApDriver::Apsb;
        let uses_worklists = self.inner.frontier != FrontierMode::FullScan;

        let mut state = GpuState::new_in(g, &init, ctx.pool());
        let mut clocks = ShardClocks::new(k);
        let mut cardinality = init.cardinality();

        // Per-shard local frontiers (Compacted phases only) and the
        // per-shard claim buffers the exchange router consumes per level.
        let per_shard_cap = g.nc / k + 1;
        let (mut frontiers, mut nexts): (Vec<Vec<u32>>, Vec<Vec<u32>>) = if uses_worklists {
            (
                (0..k).map(|_| ctx.lease_worklist_u32(per_shard_cap)).collect(),
                (0..k).map(|_| ctx.lease_worklist_u32(per_shard_cap)).collect(),
            )
        } else {
            ((0..k).map(|_| Vec::new()).collect(), (0..k).map(|_| Vec::new()).collect())
        };
        let mut claims: Vec<Vec<u32>> =
            (0..k).map(|_| ctx.lease_worklist_u32(per_shard_cap)).collect();
        let mut endpoints = ctx.lease_worklist_u32(g.nr);
        // global worklist the replicated init emits before it is split by
        // owner into the per-shard frontiers
        let mut seed_frontier = ctx.lease_worklist_u32(g.nc);
        // scratch for the exchange router: (msgs, words) per source shard
        let mut per_source: Vec<(u64, u64)> = vec![(0, 0); k];
        let mut dest_items: Vec<u64> = vec![0; k];
        let mut outcome = RunOutcome::Complete;

        loop {
            if let Some(trip) = ctx.checkpoint() {
                outcome = trip;
                break;
            }
            // per-phase frontier mode, same density rule as the unsharded
            // driver's Adaptive handling
            let compacted = match self.inner.frontier {
                FrontierMode::FullScan => false,
                FrontierMode::Compacted => true,
                FrontierMode::Adaptive => {
                    (g.nc - cardinality) * crate::gpu::config::ADAPTIVE_DENSITY_DIV < g.nc
                }
            };
            // ---- replicated phase init: every device re-derives the
            // phase state from its replicated row/column arrays, so the
            // work is billed once per device (charge_replicated) and no
            // exchange is needed — each shard keeps only its own residents
            // of the emitted worklist.
            let mut scratch = DeviceClock::default();
            if compacted {
                init_bfs_array_frontier(&mut state, cfg, with_root, &mut seed_frontier, &mut scratch);
                for f in frontiers.iter_mut() {
                    f.clear();
                }
                for n in nexts.iter_mut() {
                    n.clear();
                }
                for &c in seed_frontier.iter() {
                    frontiers[part.owner_of(c as usize)].push(c);
                }
            } else {
                init_bfs_array(&mut state, cfg, with_root, &mut scratch);
            }
            let init_par0 = clocks.makespan().parallel_cycles;
            clocks.charge_replicated(&scratch);
            if let Some(t) = ctx.trace() {
                let par1 = clocks.makespan().parallel_cycles;
                t.bsp_span(
                    "init_replicated",
                    init_par0,
                    par1 - init_par0,
                    vec![("compacted", u64::from(compacted)), ("launches", scratch.launches)],
                );
            }
            endpoints.clear();

            state.augmenting_path_found = false;
            let mut bfs_level = L0;
            let mut launches = 0u32;
            loop {
                state.vertex_inserted = false;
                let level_par0 = clocks.makespan().parallel_cycles;
                if compacted {
                    let global: u64 = frontiers.iter().map(|f| f.len() as u64).sum();
                    ctx.stats.frontier_total += global;
                    ctx.stats.frontier_peak = ctx.stats.frontier_peak.max(global);
                }
                // ---- one BFS level, shard by shard (shard order is the
                // legal serialization of the K concurrent devices)
                for s in 0..k {
                    claims[s].clear();
                    let shard_par0 = clocks.clock_mut(s).parallel_cycles;
                    let items = if compacted {
                        frontiers[s].len() as u64
                    } else {
                        part.range(s).len() as u64
                    };
                    let scanned = if compacted {
                        if frontiers[s].is_empty() {
                            continue; // idle device: no launch, no charge
                        }
                        match self.inner.kernel {
                            BfsKernel::GpuBfs => gpubfs_frontier(
                                g,
                                &mut state,
                                bfs_level,
                                &frontiers[s],
                                &mut claims[s],
                                &mut endpoints,
                                cfg,
                                clocks.clock_mut(s),
                            ),
                            BfsKernel::GpuBfsWr => gpubfs_wr_frontier(
                                g,
                                &mut state,
                                bfs_level,
                                &frontiers[s],
                                &mut claims[s],
                                &mut endpoints,
                                cfg,
                                improved_wr,
                                clocks.clock_mut(s),
                            ),
                        }
                    } else {
                        let range = part.range(s);
                        if range.is_empty() {
                            continue; // shard owns no columns
                        }
                        match self.inner.kernel {
                            BfsKernel::GpuBfs => gpubfs_cols(
                                g,
                                &mut state,
                                bfs_level,
                                range,
                                &mut claims[s],
                                &mut endpoints,
                                cfg,
                                clocks.clock_mut(s),
                            ),
                            BfsKernel::GpuBfsWr => gpubfs_wr_cols(
                                g,
                                &mut state,
                                bfs_level,
                                range,
                                &mut claims[s],
                                &mut endpoints,
                                cfg,
                                improved_wr,
                                clocks.clock_mut(s),
                            ),
                        }
                    };
                    ctx.stats.edges_scanned += scanned;
                    launches += 1;
                    if let Some(t) = ctx.trace() {
                        let name: &'static str = match (compacted, self.inner.kernel) {
                            (true, BfsKernel::GpuBfs) => "gpubfs_frontier",
                            (true, BfsKernel::GpuBfsWr) => "gpubfs_wr_frontier",
                            (false, BfsKernel::GpuBfs) => "gpubfs_cols",
                            (false, BfsKernel::GpuBfsWr) => "gpubfs_wr_cols",
                        };
                        let dur = clocks.clock_mut(s).parallel_cycles - shard_par0;
                        t.device_span(
                            name,
                            "kernel",
                            s,
                            shard_par0,
                            dur,
                            vec![
                                ("level", (bfs_level - L0) as u64),
                                ("items", items),
                                ("edges_scanned", scanned),
                            ],
                        );
                    }
                }
                // ---- frontier exchange: route every claimed column to
                // its owning shard. Claims of home-owned columns are free;
                // a cross-shard claim ships its (row, column) endpoint
                // pair — EXCHANGE_WORDS_PER_ITEM words — and each
                // source→dest pair with traffic pays one message.
                // Endpoint rows piggyback on these messages (the rows are
                // replicated; only the claim traffic is priced), keeping
                // exchange_words an exact function of cross-shard claims.
                for s in 0..k {
                    let mut cross = 0u64;
                    dest_items.iter_mut().for_each(|d| *d = 0);
                    for &c in claims[s].iter() {
                        let d = part.owner_of(c as usize);
                        if compacted {
                            nexts[d].push(c);
                        }
                        if d != s {
                            cross += 1;
                            dest_items[d] += 1;
                        }
                    }
                    let msgs = dest_items.iter().filter(|&&n| n > 0).count() as u64;
                    per_source[s] = (msgs, cross * EXCHANGE_WORDS_PER_ITEM);
                }
                clocks.charge_exchange(&per_source);
                clocks.barrier();
                if let Some(t) = ctx.trace() {
                    let (msgs, words) = per_source
                        .iter()
                        .fold((0u64, 0u64), |(m, w), &(pm, pw)| (m + pm, w + pw));
                    let par1 = clocks.makespan().parallel_cycles;
                    t.bsp_span(
                        "level",
                        level_par0,
                        par1 - level_par0,
                        vec![
                            ("level", (bfs_level - L0) as u64),
                            ("exchange_msgs", msgs),
                            ("exchange_words", words),
                        ],
                    );
                }
                if self.inner.driver == ApDriver::Apsb && state.augmenting_path_found {
                    break;
                }
                if !state.vertex_inserted {
                    break;
                }
                if compacted {
                    std::mem::swap(&mut frontiers, &mut nexts);
                    for n in nexts.iter_mut() {
                        n.clear();
                    }
                }
                bfs_level += 1;
            }
            ctx.record_phase(launches);
            if !state.augmenting_path_found {
                break; // Berge: no augmenting path ⇒ maximum
            }

            // ---- replicated augmentation + repair: ALTERNATE and
            // FIXMATCHING run mirrored on every device over the replicated
            // row arrays. The endpoint worklist the shards accumulated is
            // always available under sharding (the exchange gathered it),
            // but the *selection cost* mirrors the unsharded driver —
            // FullScan phases are billed the O(nr) selection scan, so a
            // 1-shard run reproduces the unsharded bill exactly.
            let before = cardinality;
            ctx.stats.endpoints_total += endpoints.len() as u64;
            let mut scratch = DeviceClock::default();
            if !compacted {
                // the unsharded FullScan ALTERNATE selects `-2` rows by an
                // ascending all-rows scan; sort the gathered worklist into
                // that order so thread/warp grouping — and hence the
                // modeled step costs — match the unsharded driver exactly
                // (rows are flagged at most once per phase, so the sorted
                // list is precisely the scan's selection)
                endpoints.sort_unstable();
            }
            if improved_wr {
                if compacted {
                    charge_frontier_scan(&mut scratch, cfg.mapping, endpoints.len());
                } else {
                    charge_uniform_scan(&mut scratch, cfg.mapping, g.nr);
                }
                let chosen = wr_chosen_endpoints_from(&state, &endpoints);
                alternate(&mut state, cfg, Some(chosen.as_slice()), &mut scratch);
            } else {
                if !compacted {
                    charge_uniform_scan(&mut scratch, cfg.mapping, g.nr);
                }
                alternate(&mut state, cfg, Some(endpoints.as_slice()), &mut scratch);
            }
            let (fixes, after) = fixmatching(&mut state, cfg, &mut scratch);
            let aug_par0 = clocks.makespan().parallel_cycles;
            clocks.charge_replicated(&scratch);
            if let Some(t) = ctx.trace() {
                let par1 = clocks.makespan().parallel_cycles;
                t.bsp_span(
                    "augment_replicated",
                    aug_par0,
                    par1 - aug_par0,
                    vec![("endpoints", endpoints.len() as u64), ("fixes", fixes)],
                );
            }
            ctx.stats.fixes += fixes;
            let after = after as usize;
            debug_assert_eq!(after, state.cardinality(), "incremental |M| diverged");
            cardinality = after;
            ctx.stats.augmentations += after.saturating_sub(before) as u64;

            // same safety net as the unsharded driver: host-side, free of
            // modeled cycles, guarantees termination under any schedule
            if after <= before {
                if augment_one_sequential(g, &mut state) {
                    ctx.stats.fallbacks += 1;
                    ctx.stats.augmentations += 1;
                    cardinality += 1;
                } else {
                    break;
                }
            }
        }

        let combined = clocks.makespan();
        ctx.stats.device_cycles += combined.cycles;
        ctx.stats.device_parallel_cycles += combined.parallel_cycles;
        ctx.stats.shards = k as u64;
        ctx.stats.exchange_words += clocks.exchange_words;
        ctx.stats.exchange_steps += clocks.exchange_steps;

        if uses_worklists {
            for f in frontiers {
                ctx.give_u32(f);
            }
            for n in nexts {
                ctx.give_u32(n);
            }
        }
        for c in claims {
            ctx.give_u32(c);
        }
        ctx.give_u32(endpoints);
        ctx.give_u32(seed_frontier);
        let m = state.release(ctx.pool());
        (ctx.finish_with(m, outcome), combined)
    }
}

impl MatchingAlgorithm for ShardedGpuMatcher {
    fn name(&self) -> String {
        format!("shard{}:gpu:{}", self.shards.max(1), self.inner.name())
    }

    fn run(&self, g: &BipartiteCsr, init: Matching, ctx: &mut RunCtx) -> RunResult {
        self.run_with_clock(g, init, ctx).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::Family;
    use crate::matching::init::InitHeuristic;

    #[test]
    fn sharded_reaches_reference_on_small_graph() {
        let g = crate::graph::from_edges(3, 3, &[(0, 0), (1, 0), (1, 1), (2, 1), (2, 2)]);
        for k in [1, 2, 4] {
            let m = ShardedGpuMatcher::new(GpuConfig::default(), k);
            let r = m.run_detached(&g, Matching::empty(3, 3));
            r.matching.certify(&g).unwrap_or_else(|e| panic!("{}: {e}", m.name()));
            assert_eq!(r.matching.cardinality(), 3, "{}", m.name());
            assert_eq!(r.stats.shards, k as u64);
        }
    }

    #[test]
    fn single_shard_bills_exactly_the_unsharded_cycles() {
        // K=1 must degenerate to the unsharded driver: same cardinality
        // and identical modeled cycles in both views (no exchange, the
        // replicated phases are the whole run)
        for frontier in [FrontierMode::FullScan, FrontierMode::Compacted] {
            // pad the column side so the maximum matching leaves columns
            // unmatched: the terminal phase's frontier is then non-empty,
            // and both drivers pay the same terminal launch (the sharded
            // driver skips launches over *empty* local frontiers, which on
            // a column-perfect graph would shave the unsharded driver's
            // final empty sweep)
            let base_g = Family::Road.generate(1500, 11);
            let g = crate::graph::from_edges(base_g.nr, base_g.nc + 7, &base_g.edges());
            let init = InitHeuristic::Cheap.run(&g);
            let cfg = GpuConfig { frontier, ..Default::default() };
            let base = crate::gpu::GpuMatcher::new(cfg).run_detached(&g, init.clone());
            let sharded = ShardedGpuMatcher::new(cfg, 1).run_detached(&g, init);
            assert_eq!(base.matching.cardinality(), sharded.matching.cardinality());
            assert_eq!(
                base.stats.device_cycles, sharded.stats.device_cycles,
                "{frontier:?}: K=1 serial bill must match unsharded"
            );
            assert_eq!(
                base.stats.device_parallel_cycles, sharded.stats.device_parallel_cycles,
                "{frontier:?}: K=1 parallel bill must match unsharded"
            );
            assert_eq!(sharded.stats.exchange_words, 0, "K=1 cannot move words");
            assert_eq!(sharded.stats.exchange_steps, 0);
        }
    }

    #[test]
    fn exchange_counters_flow_into_stats() {
        let g = Family::Uniform.generate(1200, 5);
        let init = InitHeuristic::Cheap.run(&g);
        let m = ShardedGpuMatcher::new(GpuConfig::default().compacted(), 4);
        let r = m.run_detached(&g, init);
        r.matching.certify(&g).unwrap();
        assert_eq!(r.stats.shards, 4);
        // uniform random edges scatter claims across shards: some level
        // must have routed cross-shard traffic
        assert!(r.stats.exchange_steps > 0, "uniform family must exchange");
        assert!(r.stats.exchange_words > 0);
        assert_eq!(r.stats.exchange_words % EXCHANGE_WORDS_PER_ITEM, 0);
    }

    #[test]
    fn wire_name_is_stable() {
        let m = ShardedGpuMatcher::new(GpuConfig::default().compacted(), 4);
        assert_eq!(m.name(), "shard4:gpu:APFB-GPUBFS-WR-CT-FC");
    }
}
