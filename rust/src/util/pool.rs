//! A small fixed-size thread pool with scoped parallel-for, built on
//! `std::thread::scope`. `rayon` is unavailable offline, and the multicore
//! baselines (P-HK, P-PFP, P-DBFS) as well as the GPU device simulator need
//! data-parallel loops, so the repo carries its own.
//!
//! Two entry points:
//!  * [`parallel_for`] — fork/join a range across `nthreads` workers with
//!    static block-cyclic assignment (matches the paper's CT thread→column
//!    mapping and OpenMP `schedule(static)` used by Azad et al.).
//!  * [`parallel_chunks`] — contiguous chunk assignment for cache-friendly
//!    scans.
//!
//! Plus two shared-slice views for the pool's unsafe-but-disciplined
//! access patterns: [`SharedSlice`] (per-index-disjoint writes) and
//! [`AtomicCells`] (racing CAS/swap claims over an `i32` slice) — and the
//! [`WorkspacePool`], a size-keyed shelf of scratch buffers that lets the
//! coordinator's worker threads reuse `bfs_array`/frontier/visited vectors
//! across jobs instead of re-allocating them per run (see
//! `matching::algo::RunCtx`).

use crate::sanitize::race;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: honours
/// `BIMATCH_THREADS`, falls back to available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("BIMATCH_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Fork/join: run `body(thread_id)` on `nthreads` scoped threads.
/// `body` must be `Sync` so all threads can share it; per-thread work
/// partitioning is the callee's business (pass the thread id).
pub fn fork_join<F>(nthreads: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    assert!(nthreads >= 1);
    if nthreads == 1 {
        body(0);
        return;
    }
    std::thread::scope(|s| {
        for tid in 1..nthreads {
            let body = &body;
            s.spawn(move || body(tid));
        }
        body(0);
    });
}

/// Parallel for over `0..n` with block-cyclic (strided) assignment:
/// thread `t` visits `t, t+T, t+2T, ...`. This mirrors both the CUDA
/// coalesced-access pattern in the paper's CT kernels and a round-robin
/// OpenMP static schedule.
pub fn parallel_for<F>(nthreads: usize, n: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    fork_join(nthreads, |tid| {
        let mut i = tid;
        while i < n {
            body(i);
            i += nthreads;
        }
    });
}

/// Parallel for over `0..n` in contiguous chunks (cache-friendly scans).
pub fn parallel_chunks<F>(nthreads: usize, n: usize, body: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let per = n.div_ceil(nthreads);
    fork_join(nthreads, |tid| {
        let lo = tid * per;
        if lo < n {
            let hi = (lo + per).min(n);
            body(lo..hi);
        }
    });
}

/// A `&mut [T]` that can be shared across the scoped pool for kernels
/// whose writes are *per-index disjoint* (each index written by at most
/// one thread). The GPU simulator's INITBFSARRAY/FIXMATCHING parallel
/// paths use this; the borrow keeps the underlying slice exclusively
/// reserved for the wrapper's lifetime.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

/// # Safety
/// The wrapper owns the unique borrow of the slice, so moving it to
/// another thread moves that exclusive access with it; `T: Send` carries
/// the element-type requirement.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}

/// # Safety
/// Shared references only expose the unsafe `set`/`get`/`get_mut`
/// accessors, whose contracts require callers to keep concurrent
/// accesses index-disjoint — under that discipline cross-thread sharing
/// introduces no data race the caller did not already promise away.
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `v` at `i`.
    ///
    /// # Safety
    /// `i < self.len()`, and no other thread may concurrently read or
    /// write index `i`.
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        race::note(self.ptr.wrapping_add(i) as usize, race::AccessKind::NaWrite);
        // SAFETY: in-bounds per the contract; exclusivity of index `i`
        // is the caller's contract above.
        unsafe { *self.ptr.add(i) = v };
    }

    /// Read the value at `i`.
    ///
    /// # Safety
    /// `i < self.len()`, and no other thread may concurrently write
    /// index `i`.
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        race::note(self.ptr.wrapping_add(i) as usize, race::AccessKind::NaRead);
        // SAFETY: in-bounds per the contract; no concurrent writer per
        // the caller's contract above.
        unsafe { *self.ptr.add(i) }
    }

    /// Mutable access to the element at `i`, for *modeled-item-indexed*
    /// state (each item touches only its own cells).
    ///
    /// # Safety
    /// `i < self.len()`, no other thread may concurrently access index
    /// `i`, and the caller must not hold two overlapping borrows of the
    /// same index.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        race::note(self.ptr.wrapping_add(i) as usize, race::AccessKind::NaWrite);
        // SAFETY: in-bounds per the contract; exclusivity and borrow
        // non-overlap are the caller's contract above.
        unsafe { &mut *self.ptr.add(i) }
    }

    /// [`SharedSlice::get_mut`] for *host-lane-indexed* state: per-thread
    /// accumulation buffers where `i` is the worker lane id, so many
    /// modeled items on one lane legitimately reuse the slot. The race
    /// sanitizer logs this under the lane (not the current item) and only
    /// flags the slot if two distinct *lanes* write it.
    ///
    /// # Safety
    /// Same contract as [`SharedSlice::get_mut`]: `i < self.len()`, no
    /// concurrent access to index `i`, no overlapping borrows.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_lane_mut(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        race::note(self.ptr.wrapping_add(i) as usize, race::AccessKind::LaneWrite);
        // SAFETY: in-bounds per the contract; exclusivity and borrow
        // non-overlap are the caller's contract above.
        unsafe { &mut *self.ptr.add(i) }
    }
}

/// A `&mut [i32]` viewed as atomic cells, shareable across the scoped
/// pool for kernels whose writes *race* (GPUBFS level claims, ALTERNATE
/// column claims). Where [`SharedSlice`] encodes "each index has one
/// writer", `AtomicCells` encodes "any thread may CAS/swap any index" —
/// the lock-free discipline the GPU kernels would use on real hardware.
///
/// All operations are `Relaxed`: the scoped pool's join provides the
/// cross-thread happens-before at kernel-launch boundaries, and *within*
/// a launch the interleaving of claims is exactly the race the simulator
/// models (any outcome is a legal schedule; FIXMATCHING repairs the rest).
pub struct AtomicCells<'a> {
    cells: &'a [AtomicI32],
}

impl<'a> AtomicCells<'a> {
    pub fn new(slice: &'a mut [i32]) -> Self {
        // SAFETY: `AtomicI32` is guaranteed to have the same in-memory
        // representation as `i32`, and the exclusive borrow rules out any
        // non-atomic aliasing for the wrapper's lifetime.
        let cells = unsafe {
            std::slice::from_raw_parts(slice.as_mut_ptr() as *const AtomicI32, slice.len())
        };
        Self { cells }
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    #[inline]
    pub fn load(&self, i: usize) -> i32 {
        race::note(&self.cells[i] as *const AtomicI32 as usize, race::AccessKind::AtomicRead);
        self.cells[i].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn store(&self, i: usize, v: i32) {
        race::note(&self.cells[i] as *const AtomicI32 as usize, race::AccessKind::AtomicWrite);
        self.cells[i].store(v, Ordering::Relaxed)
    }

    /// Atomically replace the value at `i`, returning the previous value.
    #[inline]
    pub fn swap(&self, i: usize, v: i32) -> i32 {
        race::note(&self.cells[i] as *const AtomicI32 as usize, race::AccessKind::AtomicRmw);
        self.cells[i].swap(v, Ordering::Relaxed)
    }

    /// Compare-and-swap: set `i` to `new` iff it currently holds
    /// `current`. Returns whether this thread won the claim.
    #[inline]
    pub fn cas(&self, i: usize, current: i32, new: i32) -> bool {
        race::note(&self.cells[i] as *const AtomicI32 as usize, race::AccessKind::AtomicRmw);
        self.cells[i].compare_exchange(current, new, Ordering::Relaxed, Ordering::Relaxed).is_ok()
    }
}

/// Retention bound per typed shelf: a long-running service that sees many
/// distinct graph sizes must not accumulate every buffer size it has ever
/// allocated. When a shelf is full, `give` evicts the *smallest* shelved
/// buffer (large ones are the expensive ones to re-allocate) before
/// shelving the newcomer.
const SHELF_CAP: usize = 32;

/// One type's shelf of returned buffers, keyed by capacity. A lease takes
/// the smallest shelved buffer whose capacity covers the request (so a
/// worker that alternates between graph sizes still reuses instead of
/// allocating), clears it, and refills it to the requested length.
struct Shelf<T> {
    inner: Mutex<ShelfInner<T>>,
}

struct ShelfInner<T> {
    by_cap: BTreeMap<usize, Vec<Vec<T>>>,
    count: usize,
}

impl<T> Default for Shelf<T> {
    fn default() -> Self {
        Self { inner: Mutex::new(ShelfInner { by_cap: BTreeMap::new(), count: 0 }) }
    }
}

impl<T: Clone> Shelf<T> {
    fn lease(&self, len: usize) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        let (&cap, _) = inner.by_cap.range(len..).next()?;
        let bucket = inner.by_cap.get_mut(&cap).expect("bucket exists");
        let v = bucket.pop().expect("buckets are non-empty by invariant");
        if bucket.is_empty() {
            inner.by_cap.remove(&cap);
        }
        inner.count -= 1;
        Some(v)
    }

    fn give(&self, v: Vec<T>) {
        if v.capacity() == 0 {
            return; // nothing worth shelving
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.count >= SHELF_CAP {
            let (&cap, _) = inner.by_cap.iter().next().expect("count > 0 implies non-empty");
            if v.capacity() <= cap {
                // the newcomer is the cheapest of the lot to re-create:
                // drop it rather than evicting a larger buffer
                return;
            }
            // evict the smallest shelved buffer to bound retention
            let bucket = inner.by_cap.get_mut(&cap).expect("bucket exists");
            bucket.pop();
            if bucket.is_empty() {
                inner.by_cap.remove(&cap);
            }
            inner.count -= 1;
        }
        inner.by_cap.entry(v.capacity()).or_default().push(v);
        inner.count += 1;
    }
}

/// A shared pool of size-keyed scratch buffers. Algorithms lease their
/// per-run arrays (`bfs_array`, frontiers, visited marks, DFS pointers)
/// through `RunCtx` and give them back when the run ends; the service's
/// worker threads thereby stop paying an allocation + page-fault tax on
/// every job. Thread-safe (mutex per element type — leases are per *run*,
/// not per kernel launch, so contention is negligible).
///
/// Leased buffers arrive cleared and filled with the requested value;
/// `reuses()` counts leases served from the shelf rather than a fresh
/// allocation (the workspace-reuse tests assert on it).
#[derive(Default)]
pub struct WorkspacePool {
    i32s: Shelf<i32>,
    u32s: Shelf<u32>,
    u64s: Shelf<u64>,
    bools: Shelf<bool>,
    leases: AtomicU64,
    reuses: AtomicU64,
    returns: AtomicU64,
}

macro_rules! lease_give {
    ($lease:ident, $give:ident, $t:ty, $shelf:ident) => {
        pub fn $lease(&self, len: usize, fill: $t) -> Vec<$t> {
            self.leases.fetch_add(1, Ordering::Relaxed);
            match self.$shelf.lease(len) {
                Some(mut v) => {
                    self.reuses.fetch_add(1, Ordering::Relaxed);
                    v.clear();
                    v.resize(len, fill);
                    v
                }
                None => vec![fill; len],
            }
        }

        pub fn $give(&self, v: Vec<$t>) {
            self.returns.fetch_add(1, Ordering::Relaxed);
            self.$shelf.give(v);
        }
    };
}

impl WorkspacePool {
    pub fn new() -> Self {
        Self::default()
    }

    lease_give!(lease_i32, give_i32, i32, i32s);
    lease_give!(lease_u32, give_u32, u32, u32s);
    lease_give!(lease_u64, give_u64, u64, u64s);
    lease_give!(lease_bool, give_bool, bool, bools);

    /// Lease an *empty* u32 buffer with at least `cap_hint` capacity —
    /// the worklist path: no fill (callers only push), but still a
    /// size-fitted shelf pick so the first pushes of a large run don't
    /// immediately outgrow a tiny reused buffer.
    pub fn lease_u32_worklist(&self, cap_hint: usize) -> Vec<u32> {
        self.leases.fetch_add(1, Ordering::Relaxed);
        match self.u32s.lease(cap_hint) {
            Some(mut v) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v
            }
            None => Vec::with_capacity(cap_hint),
        }
    }

    /// [`WorkspacePool::lease_u32_worklist`] for u64 scratch: an *empty*
    /// buffer with at least `cap_hint` capacity. The device simulator's
    /// racy launch executors lease their per-launch work array through
    /// this (via `GpuState`), instead of `vec![0u64; n]` on every launch.
    pub fn lease_u64_worklist(&self, cap_hint: usize) -> Vec<u64> {
        self.leases.fetch_add(1, Ordering::Relaxed);
        match self.u64s.lease(cap_hint) {
            Some(mut v) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v
            }
            None => Vec::with_capacity(cap_hint),
        }
    }

    /// Total lease calls served (shelf hits + fresh allocations).
    pub fn leases(&self) -> u64 {
        self.leases.load(Ordering::Relaxed)
    }

    /// Leases served by reusing a previously returned buffer.
    pub fn reuses(&self) -> u64 {
        self.reuses.load(Ordering::Relaxed)
    }

    /// Buffers given back so far.
    pub fn returns(&self) -> u64 {
        self.returns.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn fork_join_runs_every_thread() {
        let hits = AtomicUsize::new(0);
        fork_join(4, |_tid| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn parallel_for_covers_range_exactly_once() {
        let n = 1000;
        let marks: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(7, n, |i| {
            marks[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(marks.iter().all(|m| m.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_chunks_covers_range() {
        let n = 1003;
        let sum = AtomicU64::new(0);
        parallel_chunks(5, n, |range| {
            let local: u64 = range.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn single_thread_path() {
        let sum = AtomicU64::new(0);
        parallel_for(1, 10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn empty_range_ok() {
        parallel_for(4, 0, |_| panic!("must not be called"));
        parallel_chunks(4, 0, |_| panic!("must not be called"));
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn atomic_cells_cas_has_exactly_one_winner() {
        let mut data = vec![-1i32; 4];
        let cells = AtomicCells::new(&mut data);
        let wins = AtomicUsize::new(0);
        fork_join(8, |tid| {
            if cells.cas(2, -1, tid as i32) {
                wins.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(wins.load(Ordering::Relaxed), 1, "exactly one CAS must win");
        assert_eq!(cells.len(), 4);
        assert!(!cells.is_empty());
        assert!(cells.load(2) >= 0);
        assert_eq!(data[0], -1, "untouched cells keep their value");
    }

    #[test]
    fn atomic_cells_swap_conserves_values() {
        // 8 threads swap their id into one cell: every displaced value is
        // returned to exactly one thread, so {initial} ∪ {ids} minus the
        // final cell value equals the multiset of returned values.
        let mut data = vec![-1i32];
        let cells = AtomicCells::new(&mut data);
        let got = Mutex::new(Vec::new());
        fork_join(8, |tid| {
            let prev = cells.swap(0, tid as i32);
            got.lock().unwrap().push(prev);
        });
        let mut seen = got.into_inner().unwrap();
        seen.push(cells.load(0));
        seen.sort_unstable();
        let mut expect: Vec<i32> = (-1..8).collect();
        expect.sort_unstable();
        assert_eq!(seen, expect);
    }

    #[test]
    fn workspace_pool_reuses_returned_buffers() {
        let pool = WorkspacePool::new();
        let a = pool.lease_i32(100, -1);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&x| x == -1));
        assert_eq!(pool.leases(), 1);
        assert_eq!(pool.reuses(), 0, "first lease must be a fresh allocation");
        let cap = a.capacity();
        pool.give_i32(a);
        let b = pool.lease_i32(100, 7);
        assert_eq!(pool.reuses(), 1, "same-size lease must come from the shelf");
        assert_eq!(b.capacity(), cap);
        assert!(b.iter().all(|&x| x == 7), "reused buffers must arrive refilled");
    }

    #[test]
    fn workspace_pool_smaller_request_reuses_larger_buffer() {
        let pool = WorkspacePool::new();
        pool.give_u32(Vec::with_capacity(512));
        let v = pool.lease_u32(64, 0);
        assert_eq!(v.len(), 64);
        assert_eq!(pool.reuses(), 1);
        // a request larger than anything shelved allocates fresh
        let w = pool.lease_u32(1024, 0);
        assert_eq!(w.len(), 1024);
        assert_eq!(pool.reuses(), 1);
    }

    #[test]
    fn workspace_pool_typed_shelves_are_independent() {
        let pool = WorkspacePool::new();
        pool.give_bool(vec![true; 32]);
        assert_eq!(pool.returns(), 1);
        // i32 lease must not consume the bool shelf
        let v = pool.lease_i32(8, 0);
        assert_eq!(v.len(), 8);
        assert_eq!(pool.reuses(), 0);
        let b = pool.lease_bool(32, false);
        assert_eq!(pool.reuses(), 1);
        assert!(b.iter().all(|&x| !x));
    }

    #[test]
    fn workspace_pool_u64_worklist_reuses_capacity() {
        // the racy-launch work array path: leased empty, given back with
        // its grown capacity, and served from the shelf next time
        let pool = WorkspacePool::new();
        let mut w = pool.lease_u64_worklist(0);
        assert!(w.is_empty());
        assert_eq!(pool.reuses(), 0);
        w.resize(256, 0);
        let cap = w.capacity();
        pool.give_u64(w);
        let again = pool.lease_u64_worklist(64);
        assert!(again.is_empty(), "worklist leases arrive empty");
        assert_eq!(again.capacity(), cap);
        assert_eq!(pool.reuses(), 1);
        // independent of the u32 shelf
        let v = pool.lease_u32_worklist(16);
        assert!(v.is_empty());
        assert_eq!(pool.reuses(), 1);
    }

    #[test]
    fn workspace_pool_zero_capacity_returns_are_dropped() {
        let pool = WorkspacePool::new();
        pool.give_u32(Vec::new());
        let v = pool.lease_u32(0, 0);
        assert!(v.is_empty());
        assert_eq!(pool.reuses(), 0, "an empty vec is not worth shelving");
    }

    #[test]
    fn workspace_pool_retention_is_bounded() {
        // a service seeing ever-new sizes must not hoard every buffer it
        // ever allocated: the shelf evicts smallest-first past SHELF_CAP
        let pool = WorkspacePool::new();
        for len in 1..=(SHELF_CAP + 10) {
            pool.give_i32(vec![0; len]);
        }
        // the small sizes were evicted; the large ones are still leasable
        let v = pool.lease_i32(SHELF_CAP + 10, 0);
        assert_eq!(v.len(), SHELF_CAP + 10);
        assert_eq!(pool.reuses(), 1, "largest buffer must survive eviction");
        pool.give_i32(v); // shelf is full again
        // a full shelf drops a small newcomer instead of evicting a
        // larger (more expensive to re-create) buffer for it
        pool.give_i32(vec![0; 2]);
        let small = pool.lease_i32(1, 0);
        assert!(
            small.capacity() > 2,
            "the tiny newcomer must not displace larger shelved buffers"
        );
    }

    #[test]
    fn shared_slice_disjoint_parallel_writes() {
        let n = 512;
        let mut data = vec![0u32; n];
        let shared = SharedSlice::new(&mut data);
        parallel_for(4, n, |i| unsafe {
            shared.set(i, i as u32 + 1);
        });
        assert_eq!(shared.len(), n);
        assert!(!shared.is_empty());
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32 + 1));
    }
}
