//! Minimal property-based testing framework (no `proptest`/`quickcheck`
//! offline). Provides seeded case generation, a configurable number of
//! cases, and greedy shrinking for the integer-vector generators we need.
//!
//! Usage:
//! ```no_run
//! use bimatch::util::qcheck::{Config, forall};
//! forall(Config::cases(64), |rng| {
//!     let n = rng.gen_range(50) + 1;
//!     // ... build input from rng, return Ok(()) or Err(description)
//!     if n <= 50 { Ok(()) } else { Err(format!("bad n={n}")) }
//! });
//! ```

use super::rng::Xoshiro256;

#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Config {
    pub fn cases(cases: usize) -> Self {
        Self { cases, seed: 0xB1A7C4 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run `prop` on `cfg.cases` seeded RNGs; panic with the failing seed and
/// message on the first failure. Each case gets an independent, derivable
/// RNG so failures are reproducible by seed.
pub fn forall<F>(cfg: Config, prop: F)
where
    F: Fn(&mut Xoshiro256) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Shrinkable random bipartite edge list: returns (nr, nc, edges). Sizes are
/// skewed small; edge count follows density drawn per-case so both sparse
/// and dense-ish cases occur.
pub fn arb_bipartite(rng: &mut Xoshiro256, max_side: usize) -> (usize, usize, Vec<(u32, u32)>) {
    let nr = rng.gen_range(max_side) + 1;
    let nc = rng.gen_range(max_side) + 1;
    let max_edges = nr * nc;
    let density = rng.next_f64() * rng.next_f64(); // bias sparse
    let m = ((max_edges as f64 * density) as usize).min(max_edges);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        edges.push((rng.gen_range(nr) as u32, rng.gen_range(nc) as u32));
    }
    edges.sort_unstable();
    edges.dedup();
    (nr, nc, edges)
}

/// Greedy shrink of a failing edge list against `still_fails`: repeatedly
/// try dropping halves, then single edges, keeping the input failing.
pub fn shrink_edges<F>(
    nr: usize,
    nc: usize,
    edges: Vec<(u32, u32)>,
    still_fails: F,
) -> Vec<(u32, u32)>
where
    F: Fn(usize, usize, &[(u32, u32)]) -> bool,
{
    let mut cur = edges;
    // halve passes
    let mut progress = true;
    while progress && cur.len() > 1 {
        progress = false;
        let half = cur.len() / 2;
        for keep_hi in [false, true] {
            let cand: Vec<_> = if keep_hi {
                cur[half..].to_vec()
            } else {
                cur[..half].to_vec()
            };
            if !cand.is_empty() && still_fails(nr, nc, &cand) {
                cur = cand;
                progress = true;
                break;
            }
        }
    }
    // single-edge drops
    let mut i = 0;
    while i < cur.len() {
        let mut cand = cur.clone();
        cand.remove(i);
        if still_fails(nr, nc, &cand) {
            cur = cand;
        } else {
            i += 1;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(Config::cases(16), |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(Config::cases(8), |rng| {
            if rng.gen_range(4) == 3 {
                Err("hit".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn arb_bipartite_in_bounds() {
        forall(Config::cases(50), |rng| {
            let (nr, nc, edges) = arb_bipartite(rng, 30);
            for &(r, c) in &edges {
                if r as usize >= nr || c as usize >= nc {
                    return Err(format!("edge ({r},{c}) out of bounds {nr}x{nc}"));
                }
            }
            // dedup'd
            let set: std::collections::HashSet<_> = edges.iter().collect();
            if set.len() != edges.len() {
                return Err("duplicate edges".into());
            }
            Ok(())
        });
    }

    #[test]
    fn shrink_finds_minimal_witness() {
        // failure condition: contains edge (1,1)
        let edges = vec![(0, 0), (1, 1), (2, 2), (3, 1)];
        let fails = |_nr: usize, _nc: usize, es: &[(u32, u32)]| es.contains(&(1, 1));
        let shrunk = shrink_edges(4, 4, edges, fails);
        assert_eq!(shrunk, vec![(1, 1)]);
    }
}
