//! Statistics used by the evaluation harness: geometric means (Table 1,
//! Fig. 5), speedup profiles (Fig. 3) and performance profiles (Fig. 4),
//! exactly as defined in the paper's §4.

/// Geometric mean of strictly-positive values. Values are clamped below at
/// `floor` (default 1e-9 s) so a 0-measurement cannot zero the mean.
pub fn geomean(values: &[f64]) -> f64 {
    geomean_floor(values, 1e-9)
}

pub fn geomean_floor(values: &[f64], floor: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let s: f64 = values.iter().map(|&v| v.max(floor).ln()).sum();
    (s / values.len() as f64).exp()
}

pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

pub fn min(values: &[f64]) -> f64 {
    values.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(values: &[f64]) -> f64 {
    values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Median (on a copy; not in-place).
pub fn median(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// One point of a cumulative profile: at threshold `x`, fraction `y` of the
/// instances satisfy the profile predicate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProfilePoint {
    pub x: f64,
    pub y: f64,
}

/// Log2-scaled *speedup profile* (paper Fig. 3). `speedups[i]` is the
/// speedup of the algorithm on instance `i` w.r.t. the reference. A point
/// (x, y) means: with probability y the algorithm obtains at least 2^x
/// speedup. `xs` are the log2-thresholds to evaluate.
pub fn speedup_profile(speedups: &[f64], xs: &[f64]) -> Vec<ProfilePoint> {
    let n = speedups.len().max(1) as f64;
    xs.iter()
        .map(|&x| {
            let t = 2f64.powf(x);
            let y = speedups.iter().filter(|&&s| s >= t).count() as f64 / n;
            ProfilePoint { x, y }
        })
        .collect()
}

/// *Performance profile* (paper Fig. 4, Dolan–Moré). `times[a][i]` is the
/// runtime of algorithm `a` on instance `i`. Returns for each algorithm the
/// fraction of instances on which it is within factor `x` of the per-
/// instance best, evaluated at each threshold in `xs`.
pub fn performance_profile(times: &[Vec<f64>], xs: &[f64]) -> Vec<Vec<ProfilePoint>> {
    if times.is_empty() {
        return vec![];
    }
    let ninst = times[0].len();
    assert!(times.iter().all(|t| t.len() == ninst), "ragged time matrix");
    // per-instance best across algorithms
    let best: Vec<f64> = (0..ninst)
        .map(|i| times.iter().map(|t| t[i]).fold(f64::INFINITY, f64::min))
        .collect();
    times
        .iter()
        .map(|t| {
            xs.iter()
                .map(|&x| {
                    let y = (0..ninst)
                        .filter(|&i| t[i] <= x * best[i].max(1e-12))
                        .count() as f64
                        / ninst.max(1) as f64;
                    ProfilePoint { x, y }
                })
                .collect()
        })
        .collect()
}

/// Render a profile as a fixed-width ASCII sparkline-style row (used by the
/// figure benches to print a terminal-friendly "figure").
pub fn render_profile_ascii(points: &[ProfilePoint], width: usize) -> String {
    // sample y at `width` evenly-spaced x positions by nearest point
    let mut s = String::with_capacity(width);
    if points.is_empty() {
        return s;
    }
    let chars = [' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    for k in 0..width {
        let idx = k * points.len() / width;
        let y = points[idx].y.clamp(0.0, 1.0);
        let c = chars[((y * 8.0).round() as usize).min(8)];
        s.push(c);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn geomean_floor_guards_zero() {
        let g = geomean(&[0.0, 1.0]);
        assert!(g > 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn speedup_profile_monotone_decreasing() {
        let sp = vec![0.5, 1.0, 2.0, 4.0, 8.0];
        let xs: Vec<f64> = (-2..=4).map(|i| i as f64).collect();
        let prof = speedup_profile(&sp, &xs);
        for w in prof.windows(2) {
            assert!(w[1].y <= w[0].y + 1e-12);
        }
        // at x=0 (speedup >= 1): 4 of 5 instances
        let at0 = prof.iter().find(|p| p.x == 0.0).unwrap();
        assert!((at0.y - 0.8).abs() < 1e-12);
    }

    #[test]
    fn performance_profile_best_algo_hits_one_at_x1() {
        // algo0 always best
        let times = vec![vec![1.0, 1.0, 1.0], vec![2.0, 3.0, 1.5]];
        let prof = performance_profile(&times, &[1.0, 2.0, 3.0]);
        assert!((prof[0][0].y - 1.0).abs() < 1e-12);
        // algo1 within 2x on instances 0 and 2 → 2/3
        assert!((prof[1][1].y - 2.0 / 3.0).abs() < 1e-12);
        // everyone within 3x
        assert!((prof[1][2].y - 1.0).abs() < 1e-12);
    }

    #[test]
    fn performance_profile_y_monotone_in_x() {
        let times = vec![vec![1.0, 5.0, 2.0], vec![3.0, 1.0, 4.0]];
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        for prof in performance_profile(&times, &xs) {
            for w in prof.windows(2) {
                assert!(w[1].y >= w[0].y - 1e-12);
            }
        }
    }

    #[test]
    fn ascii_render_has_width() {
        let pts = vec![
            ProfilePoint { x: 0.0, y: 0.0 },
            ProfilePoint { x: 1.0, y: 1.0 },
        ];
        assert_eq!(render_profile_ascii(&pts, 16).chars().count(), 16);
    }
}
