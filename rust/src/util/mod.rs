//! Infrastructure substrates built in-repo because the offline environment
//! ships no `rand`, `rayon`, `criterion`, or `proptest`: deterministic RNG,
//! timing, a scoped thread pool, evaluation statistics, a mini
//! property-testing framework, and ASCII/Markdown table rendering.

pub mod json;
pub mod pool;
pub mod qcheck;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
