//! Wall-clock timing helpers used by the bench harness and the coordinator
//! metrics. Thin wrappers over `std::time::Instant` with convenience
//! accumulation, because `criterion` is unavailable offline.

use std::time::{Duration, Instant};

/// One-shot stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Accumulating timer for repeatedly-entered code regions (e.g. "time spent
/// in BFS kernels across the whole run").
#[derive(Debug, Clone, Copy, Default)]
pub struct Accum {
    total: Duration,
    count: u64,
}

impl Accum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and add the elapsed duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.total += t.elapsed();
        self.count += 1;
        out
    }

    pub fn add(&mut self, d: Duration) {
        self.total += d;
        self.count += 1;
    }

    pub fn total_secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_secs() / self.count as f64
        }
    }
}

/// Run `f` and return (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn accum_counts() {
        let mut a = Accum::new();
        let mut x = 0u64;
        for i in 0..5 {
            x += a.time(|| i);
        }
        assert_eq!(x, 10);
        assert_eq!(a.count(), 5);
        assert!(a.total_secs() >= 0.0);
        assert!(a.mean_secs() <= a.total_secs() + 1e-12);
    }

    #[test]
    fn timed_returns_result() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
