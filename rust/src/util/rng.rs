//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so the repo carries its own
//! small, well-tested generators: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse. All workload generation,
//! permutation, and race-arbitration randomness in the library flows through
//! these so every experiment is reproducible from a single `u64` seed.

/// SplitMix64: tiny, full-period 2^64 generator; the recommended seeder for
/// xoshiro-family generators.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed the full 256-bit state from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot emit four zeros in a
        // row for any seed, but guard anyway.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        // Rejection-free fast path is fine for our non-cryptographic uses;
        // use 128-bit multiply to avoid modulo bias meaningfully.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        let n = slice.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.gen_range(i + 1);
            slice.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct values from 0..n (k << n assumed; uses a set
    /// when k is small relative to n, otherwise shuffles).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.gen_range(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }

    /// Geometric-ish power-law sample: returns value in [0, n) with
    /// P(v) ∝ (v+1)^(-alpha), via inverse-CDF on a precomputed table is
    /// avoided; instead uses the standard continuous approximation.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(alpha > 1.0);
        let u = self.next_f64();
        let xmin = 1.0f64;
        let xmax = n as f64;
        let a1 = 1.0 - alpha;
        let x = ((xmax.powf(a1) - xmin.powf(a1)) * u + xmin.powf(a1)).powf(1.0 / a1);
        ((x - 1.0) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference sequence for seed 1234567 (computed from the canonical
        // C implementation).
        let mut sm = SplitMix64::new(1234567);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::new(43);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>(), "shuffle should move things");
    }

    #[test]
    fn permutation_valid() {
        let mut r = Xoshiro256::new(5);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Xoshiro256::new(3);
        for &(n, k) in &[(100usize, 10usize), (100, 90), (10, 10), (1000, 1)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn powerlaw_skews_low() {
        let mut r = Xoshiro256::new(17);
        let n = 1000;
        let lows = (0..10_000)
            .filter(|_| r.powerlaw(n, 2.5) < n / 10)
            .count();
        assert!(lows > 8_000, "power law should concentrate mass at low values, got {lows}");
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Xoshiro256::new(23);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((2_600..3_400).contains(&hits), "p=0.3 over 10k: got {hits}");
    }
}
