//! Plain-text table rendering for the bench harness (paper tables are
//! reproduced as aligned ASCII tables on stdout and in Markdown form for
//! EXPERIMENTS.md).

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render with space padding and a separator line, first column
    /// left-aligned, the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = w[i] - c.chars().count();
                if i == 0 {
                    line.push_str(c);
                    line.push_str(&" ".repeat(pad));
                } else {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(c);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavoured Markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("| ");
        out.push_str(&self.header.join(" | "));
        out.push_str(" |\n|");
        for _ in &self.header {
            out.push_str("---|");
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str("| ");
            out.push_str(&row.join(" | "));
            out.push_str(" |\n");
        }
        out
    }
}

/// Format seconds with sensible precision (matches the paper's 2-decimal
/// second columns, switching to ms below 10 ms).
pub fn fmt_secs(s: f64) -> String {
    if s < 0.01 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}")
    }
}

pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "time"]);
        t.row(vec!["a", "1.00"]).row(vec!["longer", "12.34"]);
        let out = t.render();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].chars().count();
        assert!(lines[2..].iter().all(|l| l.chars().count() == w));
        assert!(out.contains("longer"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn fmt_secs_switches_units() {
        assert_eq!(fmt_secs(1.234), "1.23");
        assert_eq!(fmt_secs(0.001234), "1.23ms");
    }
}
