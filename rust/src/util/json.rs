//! A minimal JSON reader (serde is unavailable offline). Built for the
//! repo's own machine-readable artifacts — bench telemetry documents,
//! `events.jsonl` records, flight-recorder dumps — which the writers in
//! this crate produce, so the parser favors clarity over speed: full
//! RFC 8259 value grammar, numbers as `f64`, objects as ordered
//! key/value vectors (duplicate keys keep the last, like serde_json).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to compact JSON (object keys in `BTreeMap` order).
    /// Whole numbers in the integer-exact `f64` range print without a
    /// fraction, so round-tripped counters stay `"n":3`, not `"n":3.0`.
    pub fn to_json(&self) -> String {
        match self {
            Value::Null => "null".into(),
            Value::Bool(b) => b.to_string(),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Value::Str(s) => format!("\"{}\"", crate::trace::json_escape(s)),
            Value::Arr(a) => {
                let items: Vec<String> = a.iter().map(Value::to_json).collect();
                format!("[{}]", items.join(","))
            }
            Value::Obj(m) => {
                let items: Vec<String> = m
                    .iter()
                    .map(|(k, v)| {
                        format!("\"{}\":{}", crate::trace::json_escape(k), v.to_json())
                    })
                    .collect();
                format!("{{{}}}", items.join(","))
            }
        }
    }
}

/// Parse one complete JSON document; trailing non-whitespace is an
/// error (a truncated or concatenated document must not half-parse).
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?} at {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?} at {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape: {e}"))?;
                            self.pos += 4;
                            // surrogate pairs are not produced by this
                            // repo's writers; map lone surrogates to the
                            // replacement char rather than erroring
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar (the input is a &str, so
                    // boundaries are valid)
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xc0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Value::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_value_grammar() {
        let v = parse(
            r#"{"a": [1, -2.5, 1e3], "b": {"nested": true}, "c": null, "s": "x\n\"q\" é"}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2.5));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(1000.0));
        assert_eq!(v.get("b").unwrap().get("nested").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("c"), Some(&Value::Null));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\n\"q\" é"));
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse(" {} ").unwrap(), Value::Obj(BTreeMap::new()));
    }

    #[test]
    fn roundtrips_the_event_log_encoder() {
        // the encoder this parser exists to read back
        let line = format!(
            "{{\"ts_ms\":12,\"level\":\"info\",\"event\":\"e\",\"s\":\"{}\",\"n\":3}}",
            crate::trace::json_escape("weird \"name\"\twith\nctl")
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("weird \"name\"\twith\nctl"));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn to_json_roundtrips_through_parse() {
        let src = r#"{"a":[1,-2.5,true,null],"b":{"s":"x\n\"q\""},"big":1000}"#;
        let v = parse(src).unwrap();
        let re = v.to_json();
        assert_eq!(parse(&re).unwrap(), v, "{re}");
        assert!(re.contains("\"big\":1000"), "whole numbers stay integers: {re}");
        assert!(re.contains("-2.5"), "{re}");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated", "{\"a\":1,}"] {
            assert!(parse(bad).is_err(), "{bad:?} should not parse");
        }
    }
}
