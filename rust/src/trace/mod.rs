//! Span-based tracing for the serving stack: the measurement substrate
//! the paper's per-phase argument needs at serve time.
//!
//! The paper's analysis is entirely *per-phase* and *per-kernel* (Fig. 2
//! plots BFS kernel launches per phase; §5 attributes the GPU wins to
//! launch counts and frontier dynamics), but until this module the
//! coordinator could only report aggregate counters. The trace layer
//! records **spans** — named, timed intervals with numeric args — at
//! three granularities:
//!
//! * **root spans** per job (queue wait, graph load, init, solve,
//!   certify, WAL fsync, snapshot write, replication-ack wait), recorded
//!   by the executor in wall-clock µs;
//! * **phase spans** inside every matcher ([`crate::RunCtx::record_phase`]
//!   emits one per outer iteration, carrying the phase's kernel-launch
//!   count — the Fig. 2 series, reconstructable from one traced run);
//! * **kernel/level leaf spans** in the GPU and sharded drivers, recorded
//!   in **modeled device cycles** on per-shard tracks, carrying frontier
//!   sizes and (sharded) per-level exchange words — the BSP makespan
//!   decomposition made visible.
//!
//! ## Two timebases
//!
//! Host spans (tracks `< DEVICE_TRACK_BASE`) are µs since the job
//! started. Device spans (tracks `>= DEVICE_TRACK_BASE`) are *modeled
//! device cycles* — the same unit as `gpu::device::DeviceClock`. The
//! Chrome exporter places them in separate trace processes so the two
//! timebases are never visually conflated (one modeled cycle renders as
//! one µs on the device tracks).
//!
//! ## Cost model
//!
//! Recording is **armed per run**: a [`TraceBuf`] is handed to the
//! `RunCtx` (or kept by the executor for root spans) only when tracing is
//! enabled. Disarmed, every instrumentation site is a single
//! `Option`-is-`None` branch — no allocation, no clock read, no atomic —
//! which is what keeps `bench_ablation` device-cycle totals and
//! `bench_persist` throughput byte-identical with tracing off.
//!
//! While a run executes, span recording is lock-free: spans go into the
//! run's own `Vec` (bounded by [`TraceBuf::cap`]; overflow increments a
//! drop counter instead of reallocating without bound). Publication into
//! the shared [`TraceRing`] happens once, after the job completes: an
//! atomic head reserves a slot and a brief per-slot mutex swaps the
//! `Arc<JobTrace>` in — readers (`TRACE` verb) never block writers for
//! longer than one pointer swap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Track id of the host (wall-clock) timeline.
pub const HOST_TRACK: u32 = 0;
/// Track id of the aggregated BSP (bulk-synchronous parallel) view of a
/// sharded run: spans here measure the *parallel makespan* advance per
/// level, so their durations sum to `ShardClocks::makespan().parallel_cycles`.
pub const BSP_TRACK: u32 = 99;
/// Device tracks: shard `s` records on `DEVICE_TRACK_BASE + s`
/// (unsharded GPU runs use shard 0). Device-track timestamps are modeled
/// cycles, not µs.
pub const DEVICE_TRACK_BASE: u32 = 100;

/// One named, timed interval. `ts`/`dur` are µs on host tracks and
/// modeled device cycles on device tracks (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanEvent {
    pub name: &'static str,
    /// coarse category: "job", "phase", "kernel", "level", "exchange",
    /// "persist", "repl"
    pub cat: &'static str,
    pub track: u32,
    pub ts: u64,
    pub dur: u64,
    /// numeric arguments (launch counts, frontier sizes, words moved, …)
    pub args: Vec<(&'static str, u64)>,
}

/// Per-run span sink. Created by whoever arms tracing (the executor, the
/// profile subcommand, a test), threaded through [`crate::RunCtx`] for
/// the matcher-level spans, and drained into a [`JobTrace`] at the end.
#[derive(Debug)]
pub struct TraceBuf {
    t0: Instant,
    spans: Vec<SpanEvent>,
    cap: usize,
    dropped: u64,
    /// host-µs mark where the current matcher phase began (reset by
    /// [`TraceBuf::phase_span`]).
    phase_mark_us: u64,
}

/// Default per-job span cap: generous for any realistic job (a phase
/// emits one span, a kernel launch one leaf), bounded so a pathological
/// run cannot grow the buffer without limit.
pub const DEFAULT_SPAN_CAP: usize = 16384;

impl TraceBuf {
    pub fn new() -> Box<Self> {
        Self::with_capacity(DEFAULT_SPAN_CAP)
    }

    pub fn with_capacity(cap: usize) -> Box<Self> {
        Box::new(Self {
            t0: Instant::now(),
            spans: Vec::with_capacity(64.min(cap)),
            cap: cap.max(1),
            dropped: 0,
            phase_mark_us: 0,
        })
    }

    /// A buffer whose timebase starts at `t0` instead of now. The
    /// executor backdates to the job's submit instant so the gap between
    /// submission and execution shows up as a `queue_wait` span at the
    /// start of the timeline.
    pub fn with_origin(t0: Instant) -> Box<Self> {
        let mut b = Self::with_capacity(DEFAULT_SPAN_CAP);
        b.t0 = t0;
        b
    }

    /// µs since this trace began — the host timebase.
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    pub fn push(&mut self, ev: SpanEvent) {
        if self.spans.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.spans.push(ev);
    }

    /// Record a host-track span that began at `start_us` (from
    /// [`TraceBuf::now_us`]) and ends now.
    pub fn host_span(
        &mut self,
        name: &'static str,
        cat: &'static str,
        start_us: u64,
        args: Vec<(&'static str, u64)>,
    ) {
        let end = self.now_us();
        self.push(SpanEvent {
            name,
            cat,
            track: HOST_TRACK,
            ts: start_us,
            dur: end.saturating_sub(start_us),
            args,
        });
    }

    /// Record a device-track span in modeled cycles on shard `shard`'s
    /// track.
    pub fn device_span(
        &mut self,
        name: &'static str,
        cat: &'static str,
        shard: usize,
        ts_cycles: u64,
        dur_cycles: u64,
        args: Vec<(&'static str, u64)>,
    ) {
        self.push(SpanEvent {
            name,
            cat,
            track: DEVICE_TRACK_BASE + shard as u32,
            ts: ts_cycles,
            dur: dur_cycles,
            args,
        });
    }

    /// Record a span on the aggregated BSP track (sharded runs): the
    /// per-level advance of the parallel makespan, in modeled cycles.
    pub fn bsp_span(
        &mut self,
        name: &'static str,
        ts_cycles: u64,
        dur_cycles: u64,
        args: Vec<(&'static str, u64)>,
    ) {
        self.push(SpanEvent { name, cat: "level", track: BSP_TRACK, ts: ts_cycles, dur: dur_cycles, args });
    }

    /// Close the current matcher phase: emits a host-track `"phase"` span
    /// from the last phase mark to now, carrying the phase index and its
    /// kernel-launch count (the Fig. 2 pair), then re-marks.
    pub fn phase_span(&mut self, phase_index: u64, launches: u32) {
        let start = self.phase_mark_us;
        self.host_span("phase", "phase", start, vec![("phase", phase_index), ("launches", launches as u64)]);
        self.phase_mark_us = self.now_us();
    }

    /// Spans recorded so far (primarily for tests and the exporters).
    pub fn spans(&self) -> &[SpanEvent] {
        &self.spans
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain the buffer into its final span list.
    pub fn into_spans(self) -> (Vec<SpanEvent>, u64) {
        (self.spans, self.dropped)
    }
}

/// A completed job's trace: identity, outcome, the span list, and the
/// summary counters the JSON line leads with.
#[derive(Debug, Clone)]
pub struct JobTrace {
    pub job_id: u64,
    /// "match" | "load" | "update" | "drop" | "save" | "profile"
    pub op: &'static str,
    /// stored-graph name, when the job addressed one
    pub graph: Option<String>,
    /// resolved algorithm spec (empty for non-Match ops without a solve)
    pub algo: String,
    /// unix ms when the job started (for log correlation)
    pub start_unix_ms: u64,
    pub total_us: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub phases: u64,
    pub launches: u64,
    pub device_cycles: u64,
    pub device_parallel_cycles: u64,
    pub shards: u64,
    pub exchange_words: u64,
    pub cardinality: u64,
    pub spans: Vec<SpanEvent>,
    pub dropped_spans: u64,
}

impl JobTrace {
    /// One JSON object on one line — the `TRACE` verb's wire format.
    pub fn to_json_line(&self) -> String {
        let mut s = String::with_capacity(256 + self.spans.len() * 96);
        s.push('{');
        push_kv_u64(&mut s, "job", self.job_id);
        push_kv_str(&mut s, "op", self.op);
        match &self.graph {
            Some(g) => push_kv_str(&mut s, "graph", g),
            None => push_kv_raw(&mut s, "graph", "null"),
        }
        push_kv_str(&mut s, "algo", &self.algo);
        push_kv_u64(&mut s, "start_ms", self.start_unix_ms);
        push_kv_u64(&mut s, "total_us", self.total_us);
        push_kv_raw(&mut s, "ok", if self.ok { "true" } else { "false" });
        match &self.error {
            Some(e) => push_kv_str(&mut s, "error", e),
            None => push_kv_raw(&mut s, "error", "null"),
        }
        push_kv_u64(&mut s, "phases", self.phases);
        push_kv_u64(&mut s, "launches", self.launches);
        push_kv_u64(&mut s, "device_cycles", self.device_cycles);
        push_kv_u64(&mut s, "device_parallel_cycles", self.device_parallel_cycles);
        push_kv_u64(&mut s, "shards", self.shards);
        push_kv_u64(&mut s, "exchange_words", self.exchange_words);
        push_kv_u64(&mut s, "cardinality", self.cardinality);
        push_kv_u64(&mut s, "dropped_spans", self.dropped_spans);
        s.push_str("\"spans\":[");
        for (i, sp) in self.spans.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            push_kv_str(&mut s, "name", sp.name);
            push_kv_str(&mut s, "cat", sp.cat);
            push_kv_u64(&mut s, "track", sp.track as u64);
            push_kv_u64(&mut s, "ts", sp.ts);
            push_kv_u64(&mut s, "dur", sp.dur);
            s.push_str("\"args\":{");
            for (j, (k, v)) in sp.args.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&format!("\"{}\":{}", json_escape(k), v));
            }
            s.push_str("}}");
        }
        s.push_str("]}");
        s
    }

    /// A complete Chrome `trace_event` document (the format
    /// `chrome://tracing` and Perfetto load): host spans under one trace
    /// process in real µs, device spans under a second process where one
    /// modeled cycle renders as one µs.
    pub fn to_chrome_trace(&self) -> String {
        const HOST_PID: u32 = 1;
        const DEVICE_PID: u32 = 2;
        let mut s = String::with_capacity(512 + self.spans.len() * 128);
        s.push_str("{\"traceEvents\":[");
        // process/thread naming metadata first
        s.push_str(&format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{HOST_PID},\"tid\":0,\
             \"args\":{{\"name\":\"host (wall-clock \\u00b5s)\"}}}}"
        ));
        s.push_str(&format!(
            ",{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{DEVICE_PID},\"tid\":0,\
             \"args\":{{\"name\":\"device (modeled cycles)\"}}}}"
        ));
        let mut tracks: Vec<u32> = self.spans.iter().map(|sp| sp.track).collect();
        tracks.sort_unstable();
        tracks.dedup();
        for t in &tracks {
            let (pid, tid, name) = chrome_track(*t);
            s.push_str(&format!(
                ",{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                json_escape(&name)
            ));
        }
        for sp in &self.spans {
            let (pid, tid, _) = chrome_track(sp.track);
            s.push_str(&format!(
                ",{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":{pid},\"tid\":{tid},\"args\":{{",
                json_escape(sp.name),
                json_escape(sp.cat),
                sp.ts,
                sp.dur
            ));
            for (j, (k, v)) in sp.args.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&format!("\"{}\":{}", json_escape(k), v));
            }
            s.push_str("}}");
        }
        s.push_str(&format!(
            "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"job\":\"{}\",\"algo\":\"{}\",\
             \"op\":\"{}\",\"cardinality\":\"{}\"}}}}",
            self.job_id,
            json_escape(&self.algo),
            self.op,
            self.cardinality
        ));
        s
    }

    /// Compact host-side breakdown for the slow-request log: host-track
    /// span durations aggregated by name, first-seen order —
    /// `queue_wait=0.1ms load=2.3ms solve=812.0ms certify=31.4ms`.
    pub fn summary(&self) -> String {
        let mut names: Vec<&'static str> = Vec::new();
        let mut totals: Vec<u64> = Vec::new();
        for sp in self.spans.iter().filter(|sp| sp.track == HOST_TRACK && sp.cat != "phase") {
            match names.iter().position(|n| *n == sp.name) {
                Some(i) => totals[i] += sp.dur,
                None => {
                    names.push(sp.name);
                    totals.push(sp.dur);
                }
            }
        }
        names
            .iter()
            .zip(&totals)
            .map(|(n, us)| format!("{n}={:.1}ms", *us as f64 / 1000.0))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Map a span track to a Chrome (pid, tid, thread name).
fn chrome_track(track: u32) -> (u32, u32, String) {
    if track == HOST_TRACK {
        (1, 0, "host".to_string())
    } else if track == BSP_TRACK {
        (2, 99, "bsp makespan".to_string())
    } else if track >= DEVICE_TRACK_BASE {
        let shard = track - DEVICE_TRACK_BASE;
        (2, shard + 1, format!("shard {shard}"))
    } else {
        (1, track, format!("host track {track}"))
    }
}

fn push_kv_u64(s: &mut String, k: &str, v: u64) {
    s.push_str(&format!("\"{k}\":{v},"));
}

fn push_kv_str(s: &mut String, k: &str, v: &str) {
    s.push_str(&format!("\"{k}\":\"{}\",", json_escape(v)));
}

fn push_kv_raw(s: &mut String, k: &str, v: &str) {
    s.push_str(&format!("\"{k}\":{v},"));
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Fixed-capacity ring of the most recent job traces, shared by the
/// executor (writer) and the `TRACE` verb (reader). The head is an
/// atomic counter — a publish reserves its slot with one `fetch_add` —
/// and each slot holds its `Arc<JobTrace>` behind a mutex held only for
/// the pointer swap, so readers and writers never serialize on the ring
/// as a whole.
pub struct TraceRing {
    slots: Vec<Mutex<Option<(u64, Arc<JobTrace>)>>>,
    head: AtomicU64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Arc<Self> {
        let cap = capacity.max(1);
        Arc::new(Self {
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            head: AtomicU64::new(0),
        })
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of traces published so far (monotonic, not clamped to
    /// capacity).
    pub fn published(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    pub fn publish(&self, trace: JobTrace) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = (seq % self.slots.len() as u64) as usize;
        // brief per-slot lock: one Arc swap, never held across work
        *self.slots[slot].lock().unwrap() = Some((seq, Arc::new(trace)));
    }

    /// The most recent `last` traces, newest first, optionally filtered
    /// by stored-graph name.
    pub fn recent(&self, graph: Option<&str>, last: usize) -> Vec<Arc<JobTrace>> {
        let mut entries: Vec<(u64, Arc<JobTrace>)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            if let Some((seq, t)) = slot.lock().unwrap().as_ref() {
                if graph.map_or(true, |g| t.graph.as_deref() == Some(g)) {
                    entries.push((*seq, t.clone()));
                }
            }
        }
        entries.sort_by(|a, b| b.0.cmp(&a.0));
        entries.truncate(last);
        entries.into_iter().map(|(_, t)| t).collect()
    }
}

/// Unix wall-clock milliseconds (for trace timestamps in logs).
pub fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_trace() -> JobTrace {
        let mut buf = TraceBuf::with_capacity(8);
        buf.phase_span(0, 3);
        buf.device_span("gpubfs", "kernel", 0, 100, 4000, vec![("frontier", 17)]);
        buf.bsp_span("bsp_level", 0, 900, vec![("level", 0)]);
        let (spans, dropped) = buf.into_spans();
        JobTrace {
            job_id: 7,
            op: "match",
            graph: Some("g\"quoted".into()),
            algo: "gpu:APFB-GPUBFS-WR-CT-FC".into(),
            start_unix_ms: 1,
            total_us: 1234,
            ok: true,
            error: None,
            phases: 1,
            launches: 3,
            device_cycles: 4100,
            device_parallel_cycles: 900,
            shards: 0,
            exchange_words: 0,
            cardinality: 42,
            spans,
            dropped_spans: dropped,
        }
    }

    /// Cheap structural JSON check (no serde in the tree): balanced
    /// braces/brackets outside strings, no raw control chars.
    fn assert_balanced_json(s: &str) {
        let (mut depth_obj, mut depth_arr) = (0i64, 0i64);
        let mut in_str = false;
        let mut esc = false;
        for c in s.chars() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    in_str = false;
                } else {
                    assert!((c as u32) >= 0x20, "raw control char in JSON string");
                }
                continue;
            }
            match c {
                '"' => in_str = true,
                '{' => depth_obj += 1,
                '}' => depth_obj -= 1,
                '[' => depth_arr += 1,
                ']' => depth_arr -= 1,
                _ => {}
            }
            assert!(depth_obj >= 0 && depth_arr >= 0, "unbalanced: {s}");
        }
        assert!(!in_str, "unterminated string");
        assert_eq!(depth_obj, 0, "unbalanced objects");
        assert_eq!(depth_arr, 0, "unbalanced arrays");
    }

    #[test]
    fn span_cap_drops_instead_of_growing() {
        let mut buf = TraceBuf::with_capacity(2);
        for i in 0..5 {
            buf.host_span("x", "job", i, vec![]);
        }
        assert_eq!(buf.spans().len(), 2);
        assert_eq!(buf.dropped(), 3);
    }

    #[test]
    fn phase_span_carries_fig2_pair_and_restarts_mark() {
        let mut buf = TraceBuf::new();
        buf.phase_span(0, 4);
        buf.phase_span(1, 2);
        let spans = buf.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].args, vec![("phase", 0), ("launches", 4)]);
        assert_eq!(spans[1].args, vec![("phase", 1), ("launches", 2)]);
        assert!(spans[1].ts >= spans[0].ts, "phases are ordered");
    }

    #[test]
    fn json_line_is_escaped_and_balanced() {
        let t = demo_trace();
        let line = t.to_json_line();
        assert!(!line.contains('\n'), "one line");
        assert!(line.contains("\\\"quoted"), "graph name escaped: {line}");
        assert!(line.contains("\"spans\":["));
        assert_balanced_json(&line);
    }

    #[test]
    fn chrome_trace_has_metadata_and_both_processes() {
        let t = demo_trace();
        let doc = t.to_chrome_trace();
        assert!(doc.contains("\"traceEvents\":["));
        assert!(doc.contains("process_name"));
        assert!(doc.contains("\"ph\":\"X\""));
        // host span on pid 1, device span on pid 2
        assert!(doc.contains("\"pid\":1"));
        assert!(doc.contains("\"pid\":2"));
        assert_balanced_json(&doc);
    }

    #[test]
    fn ring_keeps_newest_and_filters_by_graph() {
        let ring = TraceRing::new(3);
        for i in 0..5u64 {
            let mut t = demo_trace();
            t.job_id = i;
            t.graph = Some(if i % 2 == 0 { "even" } else { "odd" }.to_string());
            ring.publish(t);
        }
        let recent = ring.recent(None, 10);
        assert_eq!(recent.len(), 3, "capacity bounds retention");
        assert_eq!(recent[0].job_id, 4, "newest first");
        let odd = ring.recent(Some("odd"), 10);
        assert!(odd.iter().all(|t| t.graph.as_deref() == Some("odd")));
        assert_eq!(odd[0].job_id, 3);
        let none = ring.recent(Some("absent"), 10);
        assert!(none.is_empty());
    }

    #[test]
    fn summary_aggregates_host_spans_by_name() {
        let mut buf = TraceBuf::with_capacity(16);
        buf.push(SpanEvent { name: "load", cat: "job", track: HOST_TRACK, ts: 0, dur: 1500, args: vec![] });
        buf.push(SpanEvent { name: "solve", cat: "job", track: HOST_TRACK, ts: 1500, dur: 2000, args: vec![] });
        buf.push(SpanEvent { name: "solve", cat: "job", track: HOST_TRACK, ts: 3500, dur: 500, args: vec![] });
        // phase detail and device spans stay out of the one-liner
        buf.push(SpanEvent { name: "phase", cat: "phase", track: HOST_TRACK, ts: 0, dur: 9, args: vec![] });
        buf.device_span("gpubfs", "kernel", 0, 0, 999, vec![]);
        let (spans, dropped) = buf.into_spans();
        let t = JobTrace { spans, dropped_spans: dropped, ..demo_trace() };
        assert_eq!(t.summary(), "load=1.5ms solve=2.5ms");
    }

    #[test]
    fn with_origin_backdates_the_timebase() {
        let t0 = Instant::now() - std::time::Duration::from_millis(50);
        let buf = TraceBuf::with_origin(t0);
        assert!(buf.now_us() >= 50_000, "origin is in the past: {}", buf.now_us());
    }

    #[test]
    fn json_escape_handles_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}
