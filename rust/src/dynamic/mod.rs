//! Online (incremental) maximum-cardinality matching: the subsystem that
//! turns the one-shot pipeline into a *maintained* service — graphs live
//! server-side ([`crate::coordinator::store::GraphStore`]), clients ship
//! [`DeltaBatch`] edits, and maximality is restored by [`repair`] instead
//! of a from-scratch solve.
//!
//! ## Why repair seeds from exposed vertices only
//!
//! The source paper's §4 initialization discussion is the key observation:
//! every tested algorithm is run *after* a common cheap-matching
//! initialization (Duff, Kaya & Uçar's greedy), because the expensive part
//! of maximum matching is closing the last few percent of deficiency —
//! the augmenting-path search — not the bulk pairing. Incremental
//! maintenance is that observation taken to its limit: after a small
//! batch of edge insertions/deletions, the previous *maximum* matching is
//! a near-perfect "initialization" for the new graph whose deficiency is
//! bounded by the batch size (each deleted matched edge exposes one
//! row/column pair; each insertion can admit at most one new augmenting
//! path). So the search need not start from all `O(n)` unmatched columns
//! the way a cheap-init run does — it starts from the handful of columns
//! the batch actually exposed, which is exactly the shape
//! [`crate::gpu::FrontierMode::Compacted`]'s worklist kernels are built
//! for: the seed set becomes the first BFS frontier
//! ([`crate::gpu::GpuMatcher::run_repair_with_clock`]), and per-launch
//! work is `O(|seeds| + reached edges)` instead of `O(nc)` (cf. Łupińska's
//! lock-free augmenting framework and Birn et al.'s batched parallel
//! matching in PAPERS.md).
//!
//! Seeding is an optimization, never the correctness argument: an inserted
//! edge between two matched vertices can enable an augmenting path whose
//! endpoints the batch never touched, so every repair closes with full
//! phases from all unmatched columns until Berge's condition certifies
//! maximality. `rust/tests/dynamic_repair.rs` pins repair ≡ recompute
//! across all generator families × backends × frontier modes.
//!
//! ## Layer map
//!
//! * [`delta`] — [`DeltaOp`]/[`DeltaBatch`] (edge insert/delete, column
//!   *and row* addition) and their wire format, including the stable
//!   serialization (`to_wire`/`parse_wire`/`net_from_report`) the
//!   durability layer's write-ahead log records (`crate::persist::wal`);
//! * [`graph`] — [`DynamicGraph`], the mutable overlay over
//!   [`crate::graph::csr::BipartiteCsr`] with threshold-triggered rebuild,
//!   plus [`ApplyReport`]'s wire form and net merging
//!   ([`ApplyReport::absorb`]) used by crash recovery;
//! * [`repair`] — matching patch-up + seeded augmentation through the
//!   standard [`crate::matching::algo::RunCtx`] execution API (pool,
//!   deadline, cancellation all apply).

pub mod delta;
pub mod graph;
pub mod repair;

pub use delta::{DeltaBatch, DeltaOp};
pub use graph::{ApplyReport, DynamicGraph};
pub use repair::{repair, RepairSummary};
