//! Delta batches: the unit of change an online client ships to a stored
//! graph. Three op kinds cover the workload the service sees — edge
//! insertion, edge deletion, and column (vertex) addition — batched so the
//! repair machinery amortizes one seeded augmentation pass over the whole
//! batch instead of paying per-edge.
//!
//! The wire format (server `UPDATE` verb) is deliberately flat:
//! `add=r:c,r:c del=r:c addcols=r;r|r` — comma-separated `row:col` pairs
//! for edges, and `|`-separated `;`-lists of neighbor rows for new
//! columns (an empty segment adds an isolated column).

/// One mutation of a stored bipartite graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeltaOp {
    /// Add edge (r, c). A no-op if the edge already exists.
    InsertEdge { r: u32, c: u32 },
    /// Remove edge (r, c). A no-op if the edge does not exist.
    DeleteEdge { r: u32, c: u32 },
    /// Append a new column vertex adjacent to `rows` (may be empty).
    /// The new column's id is the graph's `nc` at application time.
    AddColumn { rows: Vec<u32> },
}

/// An ordered batch of mutations, applied atomically to a
/// [`super::DynamicGraph`] (one [`super::ApplyReport`] out, one repair).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeltaBatch {
    pub ops: Vec<DeltaOp>,
}

impl DeltaBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(mut self, r: u32, c: u32) -> Self {
        self.ops.push(DeltaOp::InsertEdge { r, c });
        self
    }

    pub fn delete(mut self, r: u32, c: u32) -> Self {
        self.ops.push(DeltaOp::DeleteEdge { r, c });
        self
    }

    pub fn add_column(mut self, rows: Vec<u32>) -> Self {
        self.ops.push(DeltaOp::AddColumn { rows });
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Build a batch from the server's `UPDATE` fields. `None` fields and
    /// empty strings contribute nothing; malformed fields are rejected
    /// whole (the request never reaches the store half-parsed).
    pub fn from_wire(
        add: Option<&str>,
        del: Option<&str>,
        addcols: Option<&str>,
    ) -> Result<DeltaBatch, String> {
        let mut batch = DeltaBatch::new();
        for (r, c) in parse_edge_pairs(add.unwrap_or(""))? {
            batch.ops.push(DeltaOp::InsertEdge { r, c });
        }
        for (r, c) in parse_edge_pairs(del.unwrap_or(""))? {
            batch.ops.push(DeltaOp::DeleteEdge { r, c });
        }
        if let Some(cols) = addcols {
            for rows in parse_columns(cols)? {
                batch.ops.push(DeltaOp::AddColumn { rows });
            }
        }
        Ok(batch)
    }
}

/// Parse `"r:c,r:c,..."` (empty string → no pairs).
pub fn parse_edge_pairs(s: &str) -> Result<Vec<(u32, u32)>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        if part.is_empty() {
            continue;
        }
        let (r, c) = part
            .split_once(':')
            .ok_or_else(|| format!("bad edge {part:?} (want row:col)"))?;
        let r: u32 = r.parse().map_err(|_| format!("bad row in {part:?}"))?;
        let c: u32 = c.parse().map_err(|_| format!("bad col in {part:?}"))?;
        out.push((r, c));
    }
    Ok(out)
}

/// Parse `"r;r|r|..."`: one new column per `|`-segment, each a
/// `;`-separated neighbor-row list (an empty segment is an isolated
/// column). An empty string adds nothing.
pub fn parse_columns(s: &str) -> Result<Vec<Vec<u32>>, String> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    for seg in s.split('|') {
        let mut rows = Vec::new();
        for tok in seg.split(';') {
            if tok.is_empty() {
                continue;
            }
            rows.push(tok.parse::<u32>().map_err(|_| format!("bad row {tok:?} in addcols"))?);
        }
        out.push(rows);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_ops_in_order() {
        let b = DeltaBatch::new().insert(1, 2).delete(3, 4).add_column(vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
        assert_eq!(b.ops[0], DeltaOp::InsertEdge { r: 1, c: 2 });
        assert_eq!(b.ops[1], DeltaOp::DeleteEdge { r: 3, c: 4 });
        assert_eq!(b.ops[2], DeltaOp::AddColumn { rows: vec![0, 1] });
    }

    #[test]
    fn wire_roundtrip() {
        let b = DeltaBatch::from_wire(Some("0:1,2:3"), Some("4:5"), Some("1;2|3|")).unwrap();
        assert_eq!(
            b.ops,
            vec![
                DeltaOp::InsertEdge { r: 0, c: 1 },
                DeltaOp::InsertEdge { r: 2, c: 3 },
                DeltaOp::DeleteEdge { r: 4, c: 5 },
                DeltaOp::AddColumn { rows: vec![1, 2] },
                DeltaOp::AddColumn { rows: vec![3] },
                DeltaOp::AddColumn { rows: vec![] },
            ]
        );
    }

    #[test]
    fn wire_empty_fields_are_empty_batches() {
        assert!(DeltaBatch::from_wire(None, None, None).unwrap().is_empty());
        assert!(DeltaBatch::from_wire(Some(""), Some(""), None).unwrap().is_empty());
    }

    #[test]
    fn wire_malformed_rejected() {
        assert!(DeltaBatch::from_wire(Some("1-2"), None, None).is_err());
        assert!(DeltaBatch::from_wire(Some("x:1"), None, None).is_err());
        assert!(DeltaBatch::from_wire(None, Some("1:y"), None).is_err());
        assert!(DeltaBatch::from_wire(None, None, Some("1;q")).is_err());
    }

    #[test]
    fn parse_columns_isolated() {
        assert_eq!(parse_columns("").unwrap(), Vec::<Vec<u32>>::new());
        // a single empty segment is one isolated column
        let two = parse_columns("|").unwrap();
        assert_eq!(two, vec![Vec::<u32>::new(), Vec::<u32>::new()]);
    }
}
