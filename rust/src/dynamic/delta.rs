//! Delta batches: the unit of change an online client ships to a stored
//! graph. Four op kinds cover the workload the service sees — edge
//! insertion, edge deletion, and column/row (vertex) addition — batched so
//! the repair machinery amortizes one seeded augmentation pass over the
//! whole batch instead of paying per-edge.
//!
//! The wire format (server `UPDATE` verb) is deliberately flat:
//! `add=r:c,r:c del=r:c addcols=r;r|r addrows=c;c|c` — comma-separated
//! `row:col` pairs for edges, and `|`-separated `;`-lists of neighbor ids
//! for new vertices (an empty segment adds an isolated column/row). Fields
//! apply in a fixed canonical order — `addrows`, `addcols`, `add`, `del` —
//! so a single request can append a vertex *and* reference it from the
//! edge clauses; [`DeltaBatch::to_wire`] emits the same order, which makes
//! the wire text round-trip exactly for every batch the server builds
//! (and for the net batches [`DeltaBatch::net_from_report`] derives — the
//! form the durability layer's write-ahead log records; see
//! `crate::persist::wal`).

use super::graph::ApplyReport;
use std::collections::BTreeMap;

/// One mutation of a stored bipartite graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DeltaOp {
    /// Add edge (r, c). A no-op if the edge already exists.
    InsertEdge { r: u32, c: u32 },
    /// Remove edge (r, c). A no-op if the edge does not exist.
    DeleteEdge { r: u32, c: u32 },
    /// Append a new column vertex adjacent to `rows` (may be empty).
    /// The new column's id is the graph's `nc` at application time.
    AddColumn { rows: Vec<u32> },
    /// Append a new row vertex adjacent to `cols` (may be empty).
    /// The new row's id is the graph's `nr` at application time.
    AddRow { cols: Vec<u32> },
}

/// An ordered batch of mutations, applied atomically to a
/// [`super::DynamicGraph`] (one [`ApplyReport`] out, one repair).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DeltaBatch {
    pub ops: Vec<DeltaOp>,
}

impl DeltaBatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(mut self, r: u32, c: u32) -> Self {
        self.ops.push(DeltaOp::InsertEdge { r, c });
        self
    }

    pub fn delete(mut self, r: u32, c: u32) -> Self {
        self.ops.push(DeltaOp::DeleteEdge { r, c });
        self
    }

    pub fn add_column(mut self, rows: Vec<u32>) -> Self {
        self.ops.push(DeltaOp::AddColumn { rows });
        self
    }

    pub fn add_row(mut self, cols: Vec<u32>) -> Self {
        self.ops.push(DeltaOp::AddRow { cols });
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Build a batch from the server's `UPDATE` fields. `None` fields and
    /// empty strings contribute nothing; malformed fields are rejected
    /// whole (the request never reaches the store half-parsed). Ops are
    /// assembled in the canonical order (`addrows`, `addcols`, `add`,
    /// `del`) so edge clauses may reference vertices appended by the same
    /// request.
    pub fn from_wire(
        add: Option<&str>,
        del: Option<&str>,
        addcols: Option<&str>,
        addrows: Option<&str>,
    ) -> Result<DeltaBatch, String> {
        let mut batch = DeltaBatch::new();
        if let Some(rows) = addrows {
            for cols in parse_vertex_lists(rows, "addrows")? {
                batch.ops.push(DeltaOp::AddRow { cols });
            }
        }
        if let Some(cols) = addcols {
            for rows in parse_vertex_lists(cols, "addcols")? {
                batch.ops.push(DeltaOp::AddColumn { rows });
            }
        }
        for (r, c) in parse_edge_pairs(add.unwrap_or(""))? {
            batch.ops.push(DeltaOp::InsertEdge { r, c });
        }
        for (r, c) in parse_edge_pairs(del.unwrap_or(""))? {
            batch.ops.push(DeltaOp::DeleteEdge { r, c });
        }
        Ok(batch)
    }

    /// Parse a full wire line of space-separated clauses, e.g.
    /// `"add=0:1 del=2:3 addcols=0;1 addrows=2"`. Inverse of
    /// [`DeltaBatch::to_wire`]; unknown clauses are rejected (a WAL record
    /// is fully trusted or not at all).
    pub fn parse_wire(line: &str) -> Result<DeltaBatch, String> {
        let (mut add, mut del, mut addcols, mut addrows) = (None, None, None, None);
        for field in line.split_whitespace() {
            let (k, v) = field
                .split_once('=')
                .ok_or_else(|| format!("bad delta clause {field:?}"))?;
            match k {
                "add" => add = Some(v),
                "del" => del = Some(v),
                "addcols" => addcols = Some(v),
                "addrows" => addrows = Some(v),
                other => return Err(format!("unknown delta clause {other:?}")),
            }
        }
        Self::from_wire(add, del, addcols, addrows)
    }

    /// Render the batch in the server's `UPDATE` wire format, clauses in
    /// the canonical order (`addrows= addcols= add= del=`, empty clauses
    /// omitted). Round-trips exactly through [`DeltaBatch::parse_wire`]
    /// for batches already in canonical grouped order — which covers
    /// every batch built by `from_wire` and every net batch from
    /// [`DeltaBatch::net_from_report`]; a hand-built batch with
    /// interleaved ops is *normalized* into that order (same ops, grouped).
    pub fn to_wire(&self) -> String {
        let mut ins = Vec::new();
        let mut del = Vec::new();
        let mut cols = Vec::new();
        let mut rows = Vec::new();
        for op in &self.ops {
            match op {
                DeltaOp::InsertEdge { r, c } => ins.push(format!("{r}:{c}")),
                DeltaOp::DeleteEdge { r, c } => del.push(format!("{r}:{c}")),
                DeltaOp::AddColumn { rows } => cols.push(fmt_vertex_list(rows)),
                DeltaOp::AddRow { cols } => rows.push(fmt_vertex_list(cols)),
            }
        }
        let mut out = Vec::new();
        if !rows.is_empty() {
            out.push(format!("addrows={}", rows.join("|")));
        }
        if !cols.is_empty() {
            out.push(format!("addcols={}", cols.join("|")));
        }
        if !ins.is_empty() {
            out.push(format!("add={}", ins.join(",")));
        }
        if !del.is_empty() {
            out.push(format!("del={}", del.join(",")));
        }
        out.join(" ")
    }

    /// The canonical batch whose application reproduces `report`'s net
    /// effect on the pre-batch graph. Vertex additions come first (rows,
    /// then columns — ids are assigned by count, so the reconstructed ids
    /// match the report's), each inserted edge is attached to the added
    /// column it references (else the added row, else the `add=` clause),
    /// and net deletions close the batch. This is what the write-ahead
    /// log records: replaying it is exact regardless of how the original
    /// batch interleaved its ops, because a *net* report has no
    /// insert/delete conflicts by construction.
    pub fn net_from_report(report: &ApplyReport) -> DeltaBatch {
        // id → op index, O(log n) lookups: this runs on the durable-UPDATE
        // hot path (WAL serialization) under the graph's entry lock
        let new_cols: BTreeMap<u32, usize> = report
            .added_cols
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, i))
            .collect();
        let new_rows: BTreeMap<u32, usize> = report
            .added_rows
            .iter()
            .enumerate()
            .map(|(i, &r)| (r, i))
            .collect();
        let mut col_rows: Vec<Vec<u32>> = vec![Vec::new(); new_cols.len()];
        let mut row_cols: Vec<Vec<u32>> = vec![Vec::new(); new_rows.len()];
        let mut plain = Vec::new();
        for &(r, c) in &report.inserted {
            if let Some(&i) = new_cols.get(&c) {
                col_rows[i].push(r);
            } else if let Some(&i) = new_rows.get(&r) {
                row_cols[i].push(c);
            } else {
                plain.push((r, c));
            }
        }
        let mut batch = DeltaBatch::new();
        for cols in row_cols {
            batch = batch.add_row(cols);
        }
        for rows in col_rows {
            batch = batch.add_column(rows);
        }
        for (r, c) in plain {
            batch = batch.insert(r, c);
        }
        for &(r, c) in &report.deleted {
            batch = batch.delete(r, c);
        }
        batch
    }
}

fn fmt_vertex_list(ids: &[u32]) -> String {
    ids.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(";")
}

/// Parse `"r:c,r:c,..."` (empty string → no pairs).
pub fn parse_edge_pairs(s: &str) -> Result<Vec<(u32, u32)>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        if part.is_empty() {
            continue;
        }
        let (r, c) = part
            .split_once(':')
            .ok_or_else(|| format!("bad edge {part:?} (want row:col)"))?;
        let r: u32 = r.parse().map_err(|_| format!("bad row in {part:?}"))?;
        let c: u32 = c.parse().map_err(|_| format!("bad col in {part:?}"))?;
        out.push((r, c));
    }
    Ok(out)
}

/// Parse `"a;a|a|..."`: one new vertex per `|`-segment, each a
/// `;`-separated neighbor-id list (an empty segment is an isolated
/// vertex). An empty string adds nothing. `clause` names the wire field
/// in error messages (`addcols` neighbor ids are rows, `addrows` ids are
/// columns).
pub fn parse_vertex_lists(s: &str, clause: &str) -> Result<Vec<Vec<u32>>, String> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    for seg in s.split('|') {
        let mut ids = Vec::new();
        for tok in seg.split(';') {
            if tok.is_empty() {
                continue;
            }
            ids.push(tok.parse::<u32>().map_err(|_| format!("bad id {tok:?} in {clause}"))?);
        }
        out.push(ids);
    }
    Ok(out)
}

/// Parse `"r;r|r|..."` — kept as the historical name for the `addcols`
/// clause (see [`parse_vertex_lists`]).
pub fn parse_columns(s: &str) -> Result<Vec<Vec<u32>>, String> {
    parse_vertex_lists(s, "addcols")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_ops_in_order() {
        let b = DeltaBatch::new()
            .insert(1, 2)
            .delete(3, 4)
            .add_column(vec![0, 1])
            .add_row(vec![2]);
        assert_eq!(b.len(), 4);
        assert!(!b.is_empty());
        assert_eq!(b.ops[0], DeltaOp::InsertEdge { r: 1, c: 2 });
        assert_eq!(b.ops[1], DeltaOp::DeleteEdge { r: 3, c: 4 });
        assert_eq!(b.ops[2], DeltaOp::AddColumn { rows: vec![0, 1] });
        assert_eq!(b.ops[3], DeltaOp::AddRow { cols: vec![2] });
    }

    #[test]
    fn wire_roundtrip() {
        let b =
            DeltaBatch::from_wire(Some("0:1,2:3"), Some("4:5"), Some("1;2|3|"), Some("0;1"))
                .unwrap();
        assert_eq!(
            b.ops,
            vec![
                DeltaOp::AddRow { cols: vec![0, 1] },
                DeltaOp::AddColumn { rows: vec![1, 2] },
                DeltaOp::AddColumn { rows: vec![3] },
                DeltaOp::AddColumn { rows: vec![] },
                DeltaOp::InsertEdge { r: 0, c: 1 },
                DeltaOp::InsertEdge { r: 2, c: 3 },
                DeltaOp::DeleteEdge { r: 4, c: 5 },
            ]
        );
        // to_wire emits the canonical clause order; parse_wire inverts it
        let wire = b.to_wire();
        assert_eq!(wire, "addrows=0;1 addcols=1;2|3| add=0:1,2:3 del=4:5");
        assert_eq!(DeltaBatch::parse_wire(&wire).unwrap(), b);
    }

    #[test]
    fn wire_empty_fields_are_empty_batches() {
        assert!(DeltaBatch::from_wire(None, None, None, None).unwrap().is_empty());
        assert!(DeltaBatch::from_wire(Some(""), Some(""), None, None).unwrap().is_empty());
        assert_eq!(DeltaBatch::new().to_wire(), "");
        assert!(DeltaBatch::parse_wire("").unwrap().is_empty());
    }

    #[test]
    fn wire_malformed_rejected() {
        assert!(DeltaBatch::from_wire(Some("1-2"), None, None, None).is_err());
        assert!(DeltaBatch::from_wire(Some("x:1"), None, None, None).is_err());
        assert!(DeltaBatch::from_wire(None, Some("1:y"), None, None).is_err());
        assert!(DeltaBatch::from_wire(None, None, Some("1;q"), None).is_err());
        assert!(DeltaBatch::from_wire(None, None, None, Some("z")).is_err());
        assert!(DeltaBatch::parse_wire("add=0:1 bogus=2").is_err());
        assert!(DeltaBatch::parse_wire("naked").is_err());
    }

    #[test]
    fn parse_vertex_lists_isolated() {
        assert_eq!(parse_columns("").unwrap(), Vec::<Vec<u32>>::new());
        // a single empty segment is one isolated column
        let two = parse_columns("|").unwrap();
        assert_eq!(two, vec![Vec::<u32>::new(), Vec::<u32>::new()]);
        assert_eq!(parse_vertex_lists("3;4|", "addrows").unwrap(), vec![vec![3, 4], vec![]]);
    }

    #[test]
    fn net_from_report_routes_edges_to_their_vertex_ops() {
        let report = ApplyReport {
            // col 5 and row 7 are new; (2,5) belongs to the column op,
            // (7,1) to the row op, (0,0) to the plain add clause
            inserted: vec![(0, 0), (2, 5), (7, 1)],
            deleted: vec![(3, 3)],
            added_cols: vec![5],
            added_rows: vec![7],
            rejected: 0,
            rebuilt: false,
        };
        let b = DeltaBatch::net_from_report(&report);
        assert_eq!(
            b.ops,
            vec![
                DeltaOp::AddRow { cols: vec![1] },
                DeltaOp::AddColumn { rows: vec![2] },
                DeltaOp::InsertEdge { r: 0, c: 0 },
                DeltaOp::DeleteEdge { r: 3, c: 3 },
            ]
        );
        // an edge between two NEW vertices is attached to the column op —
        // legal because addrows precedes addcols in canonical order
        let report = ApplyReport {
            inserted: vec![(7, 5)],
            deleted: vec![],
            added_cols: vec![5],
            added_rows: vec![7],
            rejected: 0,
            rebuilt: false,
        };
        let b = DeltaBatch::net_from_report(&report);
        assert_eq!(
            b.ops,
            vec![DeltaOp::AddRow { cols: vec![] }, DeltaOp::AddColumn { rows: vec![7] }]
        );
        assert_eq!(DeltaBatch::parse_wire(&b.to_wire()).unwrap(), b);
    }
}
