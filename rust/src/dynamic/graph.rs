//! A mutable adjacency overlay over an immutable [`BipartiteCsr`] base.
//!
//! `BipartiteCsr` is the right shape for the kernels (dense pointer
//! arrays, both-side transpose) and exactly the wrong shape for edits, so
//! the dynamic layer splits the two concerns: the `base` snapshot stays
//! frozen while per-column insert/delete sets absorb churn. The *live*
//! graph is `base ∖ deleted ∪ inserted`; [`DynamicGraph::snapshot`]
//! materializes (and memoizes) it as a CSR for the matchers, and once the
//! overlay grows past a threshold fraction of the base the whole thing is
//! rebuilt into a fresh base — the classic log-structured trade: O(batch)
//! edits, O(E) compaction amortized over many batches.

use super::delta::{DeltaBatch, DeltaOp};
use crate::graph::builder::EdgeList;
use crate::graph::csr::BipartiteCsr;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

/// Overlay compaction threshold: rebuild the base CSR when the overlay
/// holds more than this fraction of the base's edges.
pub const DEFAULT_REBUILD_THRESHOLD: f64 = 0.25;

/// Net effect of one [`DynamicGraph::apply`] call, *relative to the graph
/// as it stood before the batch* (an edge inserted and then deleted by
/// the same batch appears in neither list). This is what
/// [`super::repair`] seeds from.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ApplyReport {
    /// edges present after the batch that were absent before, `(r, c)`
    pub inserted: Vec<(u32, u32)>,
    /// edges absent after the batch that were present before, `(r, c)`
    pub deleted: Vec<(u32, u32)>,
    /// ids of columns appended by the batch
    pub added_cols: Vec<u32>,
    /// ids of rows appended by the batch
    pub added_rows: Vec<u32>,
    /// ops (or neighbor ids of an `AddColumn`/`AddRow`) dropped as
    /// out-of-range or no-ops
    pub rejected: usize,
    /// whether this apply tripped a base rebuild
    pub rebuilt: bool,
}

impl ApplyReport {
    /// Nothing changed structurally (every op was a no-op or rejected).
    pub fn is_noop(&self) -> bool {
        self.inserted.is_empty()
            && self.deleted.is_empty()
            && self.added_cols.is_empty()
            && self.added_rows.is_empty()
    }

    /// Fold `next` (the report of a *later* batch against the same graph)
    /// into `self`, keeping the combined report a *net* effect relative to
    /// the graph as it stood before `self`'s batch: an edge `self`
    /// inserted that `next` deleted cancels out (and vice versa), vertex
    /// additions and counters accumulate. This is how recovery collapses a
    /// replayed WAL tail into the single report that seeds one repair —
    /// see `crate::persist::recover`.
    pub fn absorb(&mut self, next: &ApplyReport) {
        let mut ins: BTreeSet<(u32, u32)> = self.inserted.drain(..).collect();
        let mut del: BTreeSet<(u32, u32)> = self.deleted.drain(..).collect();
        for &e in &next.inserted {
            if !del.remove(&e) {
                ins.insert(e);
            }
        }
        for &e in &next.deleted {
            if !ins.remove(&e) {
                del.insert(e);
            }
        }
        self.inserted = ins.into_iter().collect();
        self.deleted = del.into_iter().collect();
        self.added_cols.extend_from_slice(&next.added_cols);
        self.added_rows.extend_from_slice(&next.added_rows);
        self.rejected += next.rejected;
        self.rebuilt |= next.rebuilt;
    }

    /// Stable single-line serialization (WAL frame payloads — each
    /// update frame carries the report its batch produced, so replay can
    /// verify it reproduced the same net effect). Inverse of
    /// [`ApplyReport::parse_wire`].
    pub fn to_wire(&self) -> String {
        let edges = |v: &[(u32, u32)]| {
            v.iter().map(|(r, c)| format!("{r}:{c}")).collect::<Vec<_>>().join(",")
        };
        let ids = |v: &[u32]| v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",");
        format!(
            "ins={} del={} cols={} rows={} rejected={} rebuilt={}",
            edges(&self.inserted),
            edges(&self.deleted),
            ids(&self.added_cols),
            ids(&self.added_rows),
            self.rejected,
            self.rebuilt as u8
        )
    }

    pub fn parse_wire(line: &str) -> Result<ApplyReport, String> {
        let mut report = ApplyReport::default();
        for field in line.split_whitespace() {
            let (k, v) =
                field.split_once('=').ok_or_else(|| format!("bad report field {field:?}"))?;
            let parse_ids = |v: &str| -> Result<Vec<u32>, String> {
                v.split(',')
                    .filter(|t| !t.is_empty())
                    .map(|t| t.parse::<u32>().map_err(|_| format!("bad id {t:?}")))
                    .collect()
            };
            match k {
                "ins" => report.inserted = super::delta::parse_edge_pairs(v)?,
                "del" => report.deleted = super::delta::parse_edge_pairs(v)?,
                "cols" => report.added_cols = parse_ids(v)?,
                "rows" => report.added_rows = parse_ids(v)?,
                "rejected" => {
                    report.rejected =
                        v.parse().map_err(|_| format!("bad rejected count {v:?}"))?
                }
                "rebuilt" => report.rebuilt = v == "1",
                other => return Err(format!("unknown report field {other:?}")),
            }
        }
        Ok(report)
    }
}

/// A server-resident mutable bipartite graph: frozen CSR base + overlay.
///
/// `PartialEq` compares the *entire* state — base CSR contents, overlay
/// maps, counters, version, memo — which is what the transactional-update
/// rollback tests lean on: a rolled-back entry must equal its pre-batch
/// clone byte-for-byte, rebuilds included.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicGraph {
    base: Arc<BipartiteCsr>,
    /// col → rows added on top of the base (includes all edges of columns
    /// appended past `base.nc`)
    ins: BTreeMap<u32, BTreeSet<u32>>,
    /// col → base rows masked out
    del: BTreeMap<u32, BTreeSet<u32>>,
    ins_count: usize,
    del_count: usize,
    nr: usize,
    nc: usize,
    /// bumped on every structural change; cached matchings are keyed on it
    version: u64,
    rebuilds: u64,
    rebuild_threshold: f64,
    /// memoized live-CSR materialization, invalidated by `apply`
    cache: Option<Arc<BipartiteCsr>>,
}

impl DynamicGraph {
    pub fn new(base: BipartiteCsr) -> Self {
        Self::from_arc(Arc::new(base))
    }

    pub fn from_arc(base: Arc<BipartiteCsr>) -> Self {
        let (nr, nc) = (base.nr, base.nc);
        Self {
            base,
            ins: BTreeMap::new(),
            del: BTreeMap::new(),
            ins_count: 0,
            del_count: 0,
            nr,
            nc,
            version: 0,
            rebuilds: 0,
            rebuild_threshold: DEFAULT_REBUILD_THRESHOLD,
            cache: None,
        }
    }

    pub fn with_rebuild_threshold(mut self, threshold: f64) -> Self {
        self.rebuild_threshold = threshold.max(0.0);
        self
    }

    /// Start the structural version counter at `base`. The graph store
    /// hands every `LOAD` a distinct base so versions never collide
    /// across re-loads of the same name — a matching cached against the
    /// old incarnation can then never pass the new one's version guard.
    pub fn with_version_base(mut self, base: u64) -> Self {
        self.version = base;
        self
    }

    pub fn nr(&self) -> usize {
        self.nr
    }

    pub fn nc(&self) -> usize {
        self.nc
    }

    /// Live edge count (base minus masked plus overlay).
    pub fn n_edges(&self) -> usize {
        self.base.n_edges() - self.del_count + self.ins_count
    }

    /// Structural version; bumped by every [`DynamicGraph::apply`] that
    /// changes anything.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Overlay size (inserted + masked edges) — what the rebuild
    /// threshold is measured against.
    pub fn overlay_edits(&self) -> usize {
        self.ins_count + self.del_count
    }

    /// Live membership test.
    pub fn has_edge(&self, r: u32, c: u32) -> bool {
        if (r as usize) >= self.nr || (c as usize) >= self.nc {
            return false;
        }
        if let Some(set) = self.ins.get(&c) {
            if set.contains(&r) {
                return true;
            }
        }
        if (c as usize) < self.base.nc && self.base.has_edge(r as usize, c as usize) {
            return !self.del.get(&c).is_some_and(|s| s.contains(&r));
        }
        false
    }

    /// Apply a batch in op order; returns the *net* structural change.
    /// Out-of-range edges (and rows of an `AddColumn`) are counted under
    /// `rejected` rather than failing the batch — the service treats a
    /// delta stream as best-effort per element, all-or-nothing per field
    /// parse (see `DeltaBatch::from_wire`).
    pub fn apply(&mut self, batch: &DeltaBatch) -> ApplyReport {
        let mut net_ins: BTreeSet<(u32, u32)> = BTreeSet::new();
        let mut net_del: BTreeSet<(u32, u32)> = BTreeSet::new();
        let mut added_cols = Vec::new();
        let mut added_rows = Vec::new();
        let mut rejected = 0usize;
        for op in &batch.ops {
            match op {
                DeltaOp::InsertEdge { r, c } => {
                    let (r, c) = (*r, *c);
                    if (r as usize) >= self.nr || (c as usize) >= self.nc || self.has_edge(r, c) {
                        rejected += 1;
                        continue;
                    }
                    self.insert_live(r, c);
                    // net bookkeeping: re-inserting an edge this batch
                    // deleted restores the pre-batch state
                    if !net_del.remove(&(r, c)) {
                        net_ins.insert((r, c));
                    }
                }
                DeltaOp::DeleteEdge { r, c } => {
                    let (r, c) = (*r, *c);
                    if !self.has_edge(r, c) {
                        rejected += 1;
                        continue;
                    }
                    self.delete_live(r, c);
                    if !net_ins.remove(&(r, c)) {
                        net_del.insert((r, c));
                    }
                }
                DeltaOp::AddColumn { rows } => {
                    let c = self.nc as u32;
                    self.nc += 1;
                    let mut set = BTreeSet::new();
                    for &r in rows {
                        if (r as usize) < self.nr {
                            if set.insert(r) {
                                net_ins.insert((r, c));
                            }
                        } else {
                            rejected += 1;
                        }
                    }
                    self.ins_count += set.len();
                    self.ins.insert(c, set);
                    added_cols.push(c);
                }
                DeltaOp::AddRow { cols } => {
                    // symmetric to AddColumn, but the overlay is keyed by
                    // column: the new row's edges scatter into the
                    // per-column insert sets (the base has no row `r`, so
                    // they can never be base unmaskings)
                    let r = self.nr as u32;
                    self.nr += 1;
                    for &c in cols {
                        if (c as usize) < self.nc {
                            // duplicate cols in the list dedup silently,
                            // matching AddColumn's row-list behavior
                            if self.ins.entry(c).or_default().insert(r) {
                                self.ins_count += 1;
                                net_ins.insert((r, c));
                            }
                        } else {
                            rejected += 1;
                        }
                    }
                    added_rows.push(r);
                }
            }
        }
        let changed = !(net_ins.is_empty()
            && net_del.is_empty()
            && added_cols.is_empty()
            && added_rows.is_empty());
        let mut report = ApplyReport {
            inserted: net_ins.into_iter().collect(),
            deleted: net_del.into_iter().collect(),
            added_cols,
            added_rows,
            rejected,
            rebuilt: false,
        };
        if changed {
            self.version += 1;
            self.cache = None;
            report.rebuilt = self.maybe_rebuild();
        }
        report
    }

    fn insert_live(&mut self, r: u32, c: u32) {
        // a masked base edge comes back by unmasking; anything else goes
        // into the overlay
        if (c as usize) < self.base.nc && self.base.has_edge(r as usize, c as usize) {
            let set = self.del.get_mut(&c).expect("absent base edge must be masked");
            assert!(set.remove(&r), "absent base edge must be masked");
            if set.is_empty() {
                self.del.remove(&c);
            }
            self.del_count -= 1;
        } else if self.ins.entry(c).or_default().insert(r) {
            self.ins_count += 1;
        }
    }

    fn delete_live(&mut self, r: u32, c: u32) {
        if let Some(set) = self.ins.get_mut(&c) {
            if set.remove(&r) {
                if set.is_empty() && (c as usize) < self.base.nc {
                    self.ins.remove(&c);
                }
                self.ins_count -= 1;
                return;
            }
        }
        if self.del.entry(c).or_default().insert(r) {
            self.del_count += 1;
        }
    }

    fn maybe_rebuild(&mut self) -> bool {
        let budget = (self.base.n_edges().max(64) as f64 * self.rebuild_threshold) as usize;
        if self.overlay_edits() <= budget {
            return false;
        }
        self.base = Arc::new(self.materialize());
        self.ins.clear();
        self.del.clear();
        self.ins_count = 0;
        self.del_count = 0;
        self.rebuilds += 1;
        true
    }

    /// Materialize the live graph as a fresh CSR (O(E)).
    fn materialize(&self) -> BipartiteCsr {
        let mut el = EdgeList::with_capacity(self.nr, self.nc, self.n_edges());
        for c in 0..self.nc {
            let cu = c as u32;
            if c < self.base.nc {
                let masked = self.del.get(&cu);
                for &r in self.base.col_neighbors(c) {
                    if !masked.is_some_and(|s| s.contains(&r)) {
                        el.add(r as usize, c);
                    }
                }
            }
            if let Some(set) = self.ins.get(&cu) {
                for &r in set {
                    el.add(r as usize, c);
                }
            }
        }
        el.build()
    }

    /// The live graph as a CSR the matchers can run on. Clean graphs hand
    /// back the base for free; dirty ones materialize once and memoize
    /// until the next apply.
    pub fn snapshot(&mut self) -> Arc<BipartiteCsr> {
        // vertex counts must match too: an appended *isolated* row/column
        // leaves the overlay empty yet changes the graph's shape
        if self.overlay_edits() == 0 && self.nc == self.base.nc && self.nr == self.base.nr {
            return self.base.clone();
        }
        if let Some(c) = &self.cache {
            return c.clone();
        }
        let g = Arc::new(self.materialize());
        self.cache = Some(g.clone());
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;

    fn small() -> DynamicGraph {
        // 3 rows x 3 cols, diagonal + (0,1)
        DynamicGraph::new(from_edges(3, 3, &[(0, 0), (1, 1), (2, 2), (0, 1)]))
    }

    #[test]
    fn insert_delete_roundtrip() {
        let mut g = small();
        assert_eq!(g.n_edges(), 4);
        let rep = g.apply(&DeltaBatch::new().insert(2, 0).delete(0, 1));
        assert_eq!(rep.inserted, vec![(2, 0)]);
        assert_eq!(rep.deleted, vec![(0, 1)]);
        assert_eq!(rep.rejected, 0);
        assert!(!rep.is_noop());
        assert_eq!(g.n_edges(), 4);
        assert!(g.has_edge(2, 0));
        assert!(!g.has_edge(0, 1));
        assert_eq!(g.version(), 1);
        let s = g.snapshot();
        assert!(s.validate().is_ok());
        assert!(s.has_edge(2, 0) && !s.has_edge(0, 1));
        // undo both: back to the base edge set, version still advances
        let rep = g.apply(&DeltaBatch::new().delete(2, 0).insert(0, 1));
        assert_eq!(rep.inserted, vec![(0, 1)]);
        assert_eq!(rep.deleted, vec![(2, 0)]);
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.overlay_edits(), 0, "masking must cancel, not accumulate");
        assert_eq!(g.version(), 2);
    }

    #[test]
    fn net_report_cancels_within_one_batch() {
        let mut g = small();
        let rep = g.apply(&DeltaBatch::new().insert(2, 0).delete(2, 0).delete(1, 1).insert(1, 1));
        assert!(rep.is_noop(), "{rep:?}");
        assert_eq!(g.n_edges(), 4);
        assert_eq!(g.overlay_edits(), 0);
    }

    #[test]
    fn noops_and_out_of_range_rejected() {
        let mut g = small();
        let rep = g.apply(
            &DeltaBatch::new()
                .insert(0, 0) // already present
                .delete(2, 0) // absent
                .insert(9, 0) // row out of range
                .delete(0, 9), // col out of range
        );
        assert!(rep.is_noop());
        assert_eq!(rep.rejected, 4);
        assert_eq!(g.version(), 0, "no structural change, no version bump");
    }

    #[test]
    fn add_column_appends_and_dedups() {
        let mut g = small();
        let rep = g.apply(&DeltaBatch::new().add_column(vec![1, 0, 1, 7]).add_column(vec![]));
        assert_eq!(rep.added_cols, vec![3, 4]);
        assert_eq!(rep.rejected, 1, "row 7 is out of range");
        assert_eq!(rep.inserted, vec![(0, 3), (1, 3)]);
        assert_eq!(g.nc(), 5);
        assert_eq!(g.n_edges(), 6);
        let s = g.snapshot();
        assert_eq!(s.nc, 5);
        assert_eq!(s.col_neighbors(3), &[0, 1]);
        assert_eq!(s.col_degree(4), 0);
        assert!(s.validate().is_ok());
        // edges of a fresh column are live and deletable
        let rep = g.apply(&DeltaBatch::new().delete(0, 3));
        assert_eq!(rep.deleted, vec![(0, 3)]);
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn snapshot_is_memoized_and_invalidated() {
        let mut g = small();
        // clean: snapshot IS the base (no copy)
        let s0 = g.snapshot();
        assert!(Arc::ptr_eq(&s0, &g.snapshot()));
        g.apply(&DeltaBatch::new().insert(2, 0));
        let s1 = g.snapshot();
        assert!(!Arc::ptr_eq(&s0, &s1));
        assert!(Arc::ptr_eq(&s1, &g.snapshot()), "dirty snapshot must be memoized");
        g.apply(&DeltaBatch::new().delete(2, 0));
        let s2 = g.snapshot();
        assert!(!Arc::ptr_eq(&s1, &s2), "apply must invalidate the memo");
    }

    #[test]
    fn threshold_triggers_rebuild() {
        // tiny threshold: any overlay trips compaction back into the base
        let mut g = small().with_rebuild_threshold(0.0);
        let rep = g.apply(&DeltaBatch::new().insert(2, 0).delete(1, 1));
        assert!(rep.rebuilt);
        assert_eq!(g.rebuilds(), 1);
        assert_eq!(g.overlay_edits(), 0, "rebuild folds the overlay into the base");
        assert!(g.has_edge(2, 0) && !g.has_edge(1, 1));
        assert_eq!(g.n_edges(), 4);
        let s = g.snapshot();
        assert!(s.validate().is_ok());
        // and with the default threshold a single edit does NOT rebuild
        let mut g = small();
        assert!(!g.apply(&DeltaBatch::new().insert(2, 0)).rebuilt);
        assert_eq!(g.rebuilds(), 0);
    }

    #[test]
    fn add_row_appends_and_scatters_edges() {
        let mut g = small();
        let rep = g.apply(&DeltaBatch::new().add_row(vec![0, 2, 0, 9]).add_row(vec![]));
        assert_eq!(rep.added_rows, vec![3, 4]);
        assert_eq!(rep.rejected, 1, "col 9 is out of range");
        assert_eq!(rep.inserted, vec![(3, 0), (3, 2)]);
        assert_eq!(g.nr(), 5);
        assert_eq!(g.n_edges(), 6);
        let s = g.snapshot();
        assert_eq!(s.nr, 5);
        assert_eq!(s.row_neighbors(3), &[0, 2]);
        assert_eq!(s.row_degree(4), 0);
        assert!(s.validate().is_ok());
        // the new row's edges are live and deletable
        let rep = g.apply(&DeltaBatch::new().delete(3, 0));
        assert_eq!(rep.deleted, vec![(3, 0)]);
        assert!(!g.has_edge(3, 0));
        // and an edge into the new row can be added after the fact
        let rep = g.apply(&DeltaBatch::new().insert(4, 1));
        assert_eq!(rep.inserted, vec![(4, 1)]);
        assert!(g.snapshot().has_edge(4, 1));
    }

    #[test]
    fn isolated_row_changes_the_snapshot_shape() {
        // regression: an isolated appended row leaves the overlay empty,
        // so the clean-graph fast path must not hand back the old base
        let mut g = small();
        let rep = g.apply(&DeltaBatch::new().add_row(vec![]));
        assert_eq!(rep.added_rows, vec![3]);
        assert!(!rep.is_noop());
        assert_eq!(g.overlay_edits(), 0);
        let s = g.snapshot();
        assert_eq!((s.nr, s.nc), (4, 3));
        assert!(s.validate().is_ok());
    }

    #[test]
    fn absorb_cancels_across_reports() {
        let mut g = small();
        let rep1 = g.apply(&DeltaBatch::new().insert(2, 0).delete(0, 0).add_column(vec![1]));
        let mut acc = rep1.clone();
        // second batch: delete what the first inserted (incl. the new
        // column's edge), restore what it deleted, add a row
        let rep2 =
            g.apply(&DeltaBatch::new().delete(2, 0).delete(1, 3).insert(0, 0).add_row(vec![2]));
        acc.absorb(&rep2);
        assert_eq!(acc.inserted, vec![(3, 2)], "only the new row's edge survives net");
        assert_eq!(acc.deleted, vec![], "delete/insert pairs cancel across batches");
        assert_eq!(acc.added_cols, vec![3]);
        assert_eq!(acc.added_rows, vec![3]);
    }

    #[test]
    fn report_wire_roundtrip() {
        let rep = ApplyReport {
            inserted: vec![(0, 1), (2, 3)],
            deleted: vec![(4, 5)],
            added_cols: vec![3, 4],
            added_rows: vec![7],
            rejected: 2,
            rebuilt: true,
        };
        let wire = rep.to_wire();
        assert_eq!(ApplyReport::parse_wire(&wire).unwrap(), rep);
        // empty report round-trips too
        let empty = ApplyReport::default();
        assert_eq!(ApplyReport::parse_wire(&empty.to_wire()).unwrap(), empty);
        assert!(ApplyReport::parse_wire("ins=0:1 wat=3").is_err());
    }

    #[test]
    fn net_batch_replays_to_the_same_state() {
        // the WAL's core guarantee: applying net_from_report(report) to a
        // copy of the pre-batch graph reproduces graph AND report exactly
        let mut g = small();
        let mut replayed = g.clone();
        let batch = DeltaBatch::new()
            .insert(2, 0)
            .delete(0, 0)
            .add_column(vec![1, 2])
            .add_row(vec![0, 3]) // col 3 is the column just added
            .delete(1, 1);
        let report = g.apply(&batch);
        let net = DeltaBatch::net_from_report(&report);
        let net_report = replayed.apply(&net);
        assert_eq!(net_report.inserted, report.inserted);
        assert_eq!(net_report.deleted, report.deleted);
        assert_eq!(net_report.added_cols, report.added_cols);
        assert_eq!(net_report.added_rows, report.added_rows);
        let (a, b) = (g.snapshot(), replayed.snapshot());
        assert_eq!((a.nr, a.nc), (b.nr, b.nc));
        assert_eq!(a.edges(), b.edges());
        assert_eq!(g.version(), replayed.version());
    }

    #[test]
    fn snapshot_equals_from_scratch_edge_set() {
        let mut g = small();
        g.apply(
            &DeltaBatch::new()
                .insert(2, 0)
                .delete(0, 0)
                .add_column(vec![2])
                .insert(1, 3), // into the column just added? no: col 3 is the new one
        );
        // expected live set: base {(1,1),(2,2),(0,1)} + (2,0) + new col3 {2, 1}
        let s = g.snapshot();
        let mut got = s.edges();
        got.sort_unstable();
        let mut want = vec![(1, 1), (2, 2), (0, 1), (2, 0), (2, 3), (1, 3)];
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
