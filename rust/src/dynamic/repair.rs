//! Incremental matching repair: after a [`DeltaBatch`] lands, only a few
//! vertices change matching status, so instead of a from-scratch solve the
//! maintained matching is patched (deleted matched edges unmatched,
//! trivially matchable insertions joined) and the augmenting-path search
//! is *seeded* from exactly the exposed columns — the sweet spot of the
//! frontier-compacted BFS kernels (paper §4's cheap-init observation taken
//! to its limit: the init here is the previous maximum matching, so the
//! deficiency to repair is `O(|batch|)`, not `O(n)`).
//!
//! Correctness does not rest on the seeds: a seeded phase that goes quiet
//! only proves the seeds are exhausted, so the drivers always close with
//! full phases from every unmatched column until Berge's condition holds
//! (an inserted edge between two *matched* vertices can enable a path no
//! exposed vertex is an endpoint of — the closing phase is what catches
//! it). The property tests in `rust/tests/dynamic_repair.rs` pin repair ≡
//! recompute across every generator family, batch shape, and backend.

use super::graph::ApplyReport;
use crate::coordinator::registry;
use crate::coordinator::spec::AlgoSpec;
use crate::gpu::GpuMatcher;
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{RunCtx, RunResult};
use crate::matching::{Matching, UNMATCHED};
use crate::runtime::Engine;
use std::sync::Arc;

/// What one [`repair`] call did, beyond the run itself.
#[derive(Debug, Clone)]
pub struct RepairSummary {
    /// the augmentation run (matching, stats, outcome) — same contract as
    /// [`crate::matching::algo::MatchingAlgorithm::run`]
    pub result: RunResult,
    /// columns the seeded first phase started from
    pub seeds: usize,
    /// matched edges the deletions severed (each exposes a row + column)
    pub dropped: usize,
    /// inserted edges joined directly because both endpoints were free
    pub joined: usize,
    /// |M′| after drops and direct joins, before augmentation — the
    /// repair's true starting point
    pub start_cardinality: usize,
}

/// Patch `prev` (the matching maintained for the pre-batch graph) onto the
/// post-batch graph `g` and restore maximality.
///
/// * deleted matched edges are unmatched; their columns seed the search;
/// * inserted edges with both endpoints free are joined outright;
/// * inserted edges with a free column seed the search, and appended
///   columns seed themselves;
/// * the remaining deficiency is closed by `spec`'s matcher: a GPU spec
///   goes through [`GpuMatcher::run_repair_with_clock`] (the seed set
///   becomes the first compacted BFS frontier), any other spec gets a
///   host-side seeded augmentation pass and then runs normally from the
///   patched matching — warm-started either way, honouring `ctx`'s
///   deadline/cancellation and leasing scratch from its pool.
///
/// Errors on a `prev` that does not belong to `g`'s row space, and on
/// specs that cannot build (XLA without an engine).
pub fn repair(
    g: &BipartiteCsr,
    mut prev: Matching,
    report: &ApplyReport,
    spec: &AlgoSpec,
    engine: Option<Arc<Engine>>,
    ctx: &mut RunCtx,
) -> Result<RepairSummary, String> {
    if prev.nr() > g.nr {
        return Err(format!("matching has {} rows, graph has only {}", prev.nr(), g.nr));
    }
    if prev.nc() > g.nc {
        return Err(format!("matching has {} cols, graph has only {}", prev.nc(), g.nc));
    }
    // vertices the batch appended (AddColumn/AddRow) enter unmatched; an
    // added row's edges ride `report.inserted`, so the loops below join or
    // seed them like any other insertion
    prev.rmatch.resize(g.nr, UNMATCHED);
    prev.cmatch.resize(g.nc, UNMATCHED);

    let mut seeds: Vec<u32> = Vec::new();
    let mut dropped = 0usize;
    for &(r, c) in &report.deleted {
        let (ru, cu) = (r as usize, c as usize);
        if cu < g.nc && ru < g.nr && prev.cmatch[cu] == r as i32 {
            prev.cmatch[cu] = UNMATCHED;
            prev.rmatch[ru] = UNMATCHED;
            dropped += 1;
            seeds.push(c);
        }
    }
    // the patched matching must be valid for the new graph before any
    // kernel consumes it — a cheap structural guarantee at the trust
    // boundary between store bookkeeping and the matchers
    prev.validate(g).map_err(|e| format!("patched matching invalid: {e}"))?;

    let mut joined = 0usize;
    for &(r, c) in &report.inserted {
        let (ru, cu) = (r as usize, c as usize);
        if cu >= g.nc || ru >= g.nr {
            continue; // same tolerance as the deleted-edge loop above
        }
        if prev.cmatch[cu] == UNMATCHED {
            if prev.rmatch[ru] == UNMATCHED {
                prev.join(ru, cu);
                joined += 1;
            } else {
                seeds.push(c);
            }
        }
        // col matched, row free: only reachable through a closing phase
    }
    seeds.extend_from_slice(&report.added_cols);
    seeds.sort_unstable();
    seeds.dedup();
    // the bounds check also covers out-of-range added_cols ids, keeping
    // the whole report surface panic-free for external callers
    seeds.retain(|&c| {
        (c as usize) < g.nc
            && prev.cmatch[c as usize] == UNMATCHED
            && g.col_degree(c as usize) > 0
    });

    let start_cardinality = prev.cardinality();
    let n_seeds = seeds.len();
    let result = match spec {
        AlgoSpec::Gpu(cfg) => GpuMatcher::new(*cfg).run_repair(g, prev, &seeds, ctx),
        other => {
            // host-side seeded pass first (counts into ctx's stats sink,
            // drained by the matcher's finish), then the matcher closes
            // from the patched matching
            let seeded_augs = augment_from_seeds(g, &mut prev, &seeds, ctx);
            ctx.stats.augmentations += seeded_augs;
            let algo = registry::build(other, engine)
                .ok_or_else(|| registry::unavailable_msg(other))?;
            algo.run(g, prev, ctx)
        }
    };
    Ok(RepairSummary { result, seeds: n_seeds, dropped, joined, start_cardinality })
}

/// Sequential seeded augmentation: one alternating BFS per seed column,
/// flipping the path if an unmatched row is reached. Scratch is leased
/// from `ctx`'s pool once; per-seed "visited" state is a version stamp
/// (bumped between seeds), so a seed's cost is its reached subgraph — not
/// an `O(nr + nc)` reset — keeping the pass at the
/// `O(|seeds| + reached edges)` the subsystem promises. The context's
/// deadline/cancellation is checked between seeds (same inter-phase
/// discipline as the matchers); a tripped pass stops early and leaves the
/// follow-up matcher run to report the outcome. Returns the number of
/// augmentations realized.
fn augment_from_seeds(g: &BipartiteCsr, m: &mut Matching, seeds: &[u32], ctx: &RunCtx) -> u64 {
    if seeds.is_empty() {
        return 0;
    }
    // `pred` is only ever read behind a current-stamp `rstamp`, so it
    // needs no reset at all
    let mut pred = ctx.lease_i32(g.nr, -1);
    let mut rstamp = ctx.lease_u32(g.nr, 0);
    let mut cstamp = ctx.lease_u32(g.nc, 0);
    let mut frontier = ctx.lease_worklist_u32(g.nc);
    let mut next = ctx.lease_worklist_u32(g.nc);
    let mut augmented = 0u64;
    for (k, &c0) in seeds.iter().enumerate() {
        if ctx.checkpoint().is_some() {
            break; // deadline/cancellation: the matcher run reports it
        }
        let stamp = k as u32 + 1;
        let c0 = c0 as usize;
        if m.cmatch[c0] != UNMATCHED {
            continue; // an earlier seed's path matched it
        }
        frontier.clear();
        next.clear();
        frontier.push(c0 as u32);
        cstamp[c0] = stamp;
        let mut endpoint = None;
        'bfs: while !frontier.is_empty() {
            for &c in &frontier {
                for &r in g.col_neighbors(c as usize) {
                    let ru = r as usize;
                    if rstamp[ru] == stamp {
                        continue;
                    }
                    rstamp[ru] = stamp;
                    pred[ru] = c as i32;
                    match m.rmatch[ru] {
                        UNMATCHED => {
                            endpoint = Some(ru);
                            break 'bfs;
                        }
                        mc => {
                            let mc = mc as usize;
                            if cstamp[mc] != stamp {
                                cstamp[mc] = stamp;
                                next.push(mc as u32);
                            }
                        }
                    }
                }
            }
            std::mem::swap(&mut frontier, &mut next);
            next.clear();
        }
        if let Some(mut r) = endpoint {
            loop {
                let c = pred[r] as usize;
                let displaced = m.cmatch[c];
                m.cmatch[c] = r as i32;
                m.rmatch[r] = c as i32;
                if displaced == UNMATCHED {
                    break;
                }
                r = displaced as usize;
            }
            augmented += 1;
        }
    }
    ctx.give_i32(pred);
    ctx.give_u32(rstamp);
    ctx.give_u32(cstamp);
    ctx.give_u32(frontier);
    ctx.give_u32(next);
    augmented
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::{DeltaBatch, DynamicGraph};
    use crate::graph::from_edges;
    use crate::matching::reference_max_cardinality;

    fn solve(g: &BipartiteCsr) -> Matching {
        let algo = registry::build_named("hk", None).unwrap();
        let m = algo.run_detached(g, Matching::empty(g.nr, g.nc)).matching;
        m.certify(g).unwrap();
        m
    }

    fn spec_cpu() -> AlgoSpec {
        "pfp".parse().unwrap()
    }

    fn spec_gpu_fc() -> AlgoSpec {
        "gpu:APFB-GPUBFS-WR-CT-FC".parse().unwrap()
    }

    #[test]
    fn deletion_of_matched_edge_repairs_to_reference() {
        // path c0-r0-c1-r1-c2-r2: perfect matching; delete a matched edge
        let base = from_edges(3, 3, &[(0, 0), (0, 1), (1, 1), (1, 2), (2, 2)]);
        let m = solve(&base);
        let mut dg = DynamicGraph::new(base);
        let (r, c) = (m.cmatch[1] as u32, 1u32);
        let report = dg.apply(&DeltaBatch::new().delete(r, c));
        let g = dg.snapshot();
        let want = reference_max_cardinality(&g);
        for spec in [spec_cpu(), spec_gpu_fc()] {
            let s = repair(&g, m.clone(), &report, &spec, None, &mut RunCtx::detached())
                .unwrap();
            s.result.matching.certify(&g).unwrap();
            assert_eq!(s.result.matching.cardinality(), want, "{spec}");
            assert_eq!(s.dropped, 1);
            assert!(s.seeds >= 1);
            assert_eq!(s.start_cardinality, m.cardinality() - 1);
        }
    }

    #[test]
    fn insertion_between_matched_vertices_needs_the_closing_phase() {
        // the seedless adversary: insert an edge whose endpoints are BOTH
        // matched, creating an augmenting path whose endpoints (free c0,
        // free r1) are untouched by the batch. Seeding alone cannot find
        // it; the drivers' closing full phase must.
        //   edges: (r0,c0) (r0,c1) (r2,c2) (r1,c2), M = {(r0,c1),(r2,c2)}
        //   — maximum: free c0 reaches only r0, whose tree dead-ends.
        //   insert (r2,c1): path c0 -r0= c1 -(new)- r2 =c2- r1 (free).
        let base = from_edges(3, 3, &[(0, 0), (0, 1), (2, 2), (1, 2)]);
        let mut m = Matching::empty(3, 3);
        m.join(0, 1);
        m.join(2, 2);
        m.certify(&base).unwrap();
        let mut dg = DynamicGraph::new(base);
        let report = dg.apply(&DeltaBatch::new().insert(2, 1));
        let g = dg.snapshot();
        assert_eq!(reference_max_cardinality(&g), 3);
        for spec in [spec_cpu(), spec_gpu_fc()] {
            let s = repair(&g, m.clone(), &report, &spec, None, &mut RunCtx::detached())
                .unwrap();
            s.result.matching.certify(&g).unwrap();
            assert_eq!(s.result.matching.cardinality(), 3, "{spec}");
            assert_eq!(s.seeds, 0, "both endpoints matched: nothing to seed");
        }
    }

    #[test]
    fn both_free_insertions_join_without_search() {
        let base = from_edges(2, 2, &[(0, 0)]);
        let m = solve(&base); // {(r0,c0)}
        let mut dg = DynamicGraph::new(base);
        let report = dg.apply(&DeltaBatch::new().insert(1, 1));
        let g = dg.snapshot();
        let s = repair(&g, m, &report, &spec_cpu(), None, &mut RunCtx::detached()).unwrap();
        assert_eq!(s.joined, 1);
        assert_eq!(s.start_cardinality, 2);
        assert_eq!(s.result.matching.cardinality(), 2);
        s.result.matching.certify(&g).unwrap();
    }

    #[test]
    fn added_column_seeds_itself() {
        let base = from_edges(2, 1, &[(0, 0), (1, 0)]);
        let m = solve(&base); // one of the two rows matched to c0
        let mut dg = DynamicGraph::new(base);
        let report = dg.apply(&DeltaBatch::new().add_column(vec![0, 1]));
        let g = dg.snapshot();
        for spec in [spec_cpu(), spec_gpu_fc()] {
            let s = repair(&g, m.clone(), &report, &spec, None, &mut RunCtx::detached())
                .unwrap();
            s.result.matching.certify(&g).unwrap();
            assert_eq!(s.result.matching.cardinality(), 2, "{spec}");
        }
    }

    #[test]
    fn mismatched_matching_rejected() {
        let g = from_edges(2, 2, &[(0, 0)]);
        let bad = Matching::empty(3, 2);
        let report = ApplyReport::default();
        assert!(repair(&g, bad, &report, &spec_cpu(), None, &mut RunCtx::detached()).is_err());
    }

    #[test]
    fn added_row_edges_join_or_augment() {
        // base: 2x2 perfect-matchable; add a row wired to both columns —
        // the matching must grow only if a column frees up, so first
        // check the joined case (free col), then the closing-phase case
        let base = from_edges(2, 2, &[(0, 0), (1, 0), (1, 1)]);
        let m = solve(&base); // cardinality 2, both cols matched
        let mut dg = DynamicGraph::new(base);
        let report = dg.apply(&DeltaBatch::new().add_row(vec![0, 1]).add_column(vec![2]));
        let g = dg.snapshot();
        assert_eq!(reference_max_cardinality(&g), 3);
        for spec in [spec_cpu(), spec_gpu_fc()] {
            let s = repair(&g, m.clone(), &report, &spec, None, &mut RunCtx::detached())
                .unwrap();
            s.result.matching.certify(&g).unwrap();
            assert_eq!(s.result.matching.cardinality(), 3, "{spec}");
        }
        // isolated row addition: nothing to repair, still maximum
        let base = from_edges(1, 1, &[(0, 0)]);
        let m = solve(&base);
        let mut dg = DynamicGraph::new(base);
        let report = dg.apply(&DeltaBatch::new().add_row(vec![]));
        let g = dg.snapshot();
        let s = repair(&g, m, &report, &spec_cpu(), None, &mut RunCtx::detached()).unwrap();
        s.result.matching.certify(&g).unwrap();
        assert_eq!(s.result.matching.cardinality(), 1);
    }

    #[test]
    fn xla_spec_without_engine_is_unavailable() {
        let g = from_edges(1, 1, &[(0, 0)]);
        let spec: AlgoSpec = "xla:apfb-full".parse().unwrap();
        let err = repair(
            &g,
            Matching::empty(1, 1),
            &ApplyReport::default(),
            &spec,
            None,
            &mut RunCtx::detached(),
        )
        .unwrap_err();
        assert!(err.contains("XLA engine"), "{err}");
    }
}
