//! Bounded MPMC job queue with blocking push (backpressure) and pop,
//! built on Mutex + Condvar (no crossbeam-channel offline). Close-able:
//! after `close()`, pops drain the remaining items then return None.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            inner: Mutex::new(Inner { items: VecDeque::with_capacity(capacity), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Block until there is room (backpressure); Err if closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking push; Err if full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item is available; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_full() {
        let q = BoundedQueue::new(1);
        q.try_push(1).unwrap();
        assert!(q.try_push(2).is_err());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert!(q.push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        // deflaked: no wall-clock sleep. `started` is a rendezvous; once
        // the producer is at (or past) the push call, `pushed` *cannot*
        // be set until we pop — the queue is full and push only returns
        // after enqueueing — so the assertions are deterministic.
        use std::sync::atomic::{AtomicBool, Ordering};
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0).unwrap();
        let started = Arc::new(AtomicBool::new(false));
        let pushed = Arc::new(AtomicBool::new(false));
        let (q2, s2, p2) = (q.clone(), started.clone(), pushed.clone());
        let h = std::thread::spawn(move || {
            s2.store(true, Ordering::SeqCst);
            q2.push(1).unwrap(); // blocks: capacity 1, queue full
            p2.store(true, Ordering::SeqCst);
        });
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        assert!(!pushed.load(Ordering::SeqCst), "push cannot complete before the pop");
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(0));
        h.join().unwrap();
        assert!(pushed.load(Ordering::SeqCst));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn mpmc_all_items_delivered() {
        // deflaked: join the producers before closing instead of hoping a
        // fixed sleep outlasts them — under load the old 100 ms window
        // closed the queue early and dropped items.
        let q = Arc::new(BoundedQueue::new(8));
        let n_items = 200;
        let consumed = Arc::new(Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            let producers: Vec<_> = (0..4)
                .map(|p| {
                    let q = q.clone();
                    s.spawn(move || {
                        for i in 0..n_items / 4 {
                            q.push(p * 1000 + i).unwrap();
                        }
                    })
                })
                .collect();
            for _ in 0..3 {
                let q = q.clone();
                let consumed = consumed.clone();
                s.spawn(move || {
                    while let Some(v) = q.pop() {
                        consumed.lock().unwrap().push(v);
                    }
                });
            }
            for h in producers {
                h.join().unwrap();
            }
            q.close();
        });
        let mut got = consumed.lock().unwrap().clone();
        assert_eq!(got.len(), n_items as usize, "no item dropped or delivered twice");
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n_items as usize, "all delivered items distinct");
    }
}
