//! Service metrics: counters and a log2-bucketed latency histogram,
//! lock-free on the hot path (atomics only).

use std::sync::atomic::{AtomicU64, Ordering};

// Bucket `i` counts latencies in `[2^i, 2^{i+1})` µs; bucket 0 also
// absorbs every sub-µs sample and bucket 31 everything above. The real
// span is therefore 1 µs … 2^31 µs (≈ 36 min) — *not* the 2^-20 s …
// 2^11 s a symmetric-around-1s reading would suggest: `bucket()` clamps
// to ≥ 1 µs, so there are no sub-µs buckets.
const N_BUCKETS: usize = 32;

#[derive(Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub certify_failures: AtomicU64,
    /// jobs that tripped their deadline (also counted in `jobs_failed`)
    pub jobs_timed_out: AtomicU64,
    /// jobs abandoned via cancellation (also counted in `jobs_failed`)
    pub jobs_cancelled: AtomicU64,
    /// successful `UPDATE` jobs (also counted in `jobs_completed`)
    pub jobs_updated: AtomicU64,
    /// successful `LOAD` jobs (graphs installed into the store)
    pub graphs_loaded: AtomicU64,
    /// successful `DROP` jobs (graphs evicted from the store)
    pub graphs_dropped: AtomicU64,
    /// graphs reconstructed from the data dir (startup recovery plus
    /// transparent reloads of evicted names)
    pub graphs_recovered: AtomicU64,
    /// graphs pushed out of memory by the `--max-graphs` LRU cap
    pub graphs_evicted: AtomicU64,
    /// write-ahead-log frames fsync'd (LOAD/DROP markers and committed
    /// UPDATE records)
    pub wal_appends: AtomicU64,
    /// snapshot files written (LOAD bases, rebuild piggybacks, `SAVE`,
    /// eviction)
    pub snapshots_written: AtomicU64,
    /// replication events published to the follower stream (primary side:
    /// snapshots, update frames, and drop markers)
    pub repl_frames_shipped: AtomicU64,
    /// replication events applied from the stream (follower side)
    pub repl_frames_applied: AtomicU64,
    /// follower acknowledgements processed (primary side)
    pub repl_acks: AtomicU64,
    /// current replication lag in events: last published sequence minus
    /// highest acked sequence (primary side; gauge, not a counter)
    pub repl_lag: AtomicU64,
    pub edges_processed: AtomicU64,
    pub matched_total: AtomicU64,
    latency: [AtomicU64; N_BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a latency: `floor(log2(µs))`, clamped into
    /// `[0, N_BUCKETS)` — sub-µs samples land in bucket 0, everything
    /// ≥ 2^31 µs in the last bucket.
    fn bucket(secs: f64) -> usize {
        let us = (secs * 1e6).max(1.0);
        (us.log2() as usize).min(N_BUCKETS - 1)
    }

    pub fn observe_latency(&self, secs: f64) {
        self.latency[Self::bucket(secs)].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn completed(&self) -> u64 {
        self.jobs_completed.load(Ordering::Relaxed)
    }

    /// approximate quantile from the log2 histogram (upper bucket bound).
    /// `q = 0.0` returns the first *non-empty* bucket's bound (the
    /// minimum observed latency's bucket), not bucket 0's.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let total: u64 = self.latency.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        // q=0 would otherwise make target 0 and `seen >= 0` trivially
        // true at bucket 0 even when that bucket is empty
        let target = (((total as f64) * q).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.latency.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (2f64.powi(i as i32 + 1)) / 1e6; // upper bound, secs
            }
        }
        f64::INFINITY
    }

    pub fn mean_latency(&self) -> f64 {
        let n = self.completed();
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// The wire report behind the server's `STATS` verb. Every counter the
    /// executor maintains is on it — including the failure-mode split
    /// (`timeout=`/`cancelled=`, which are *also* inside `failed=`), the
    /// incremental-subsystem counters (`updated=` successful UPDATE jobs,
    /// `graphs loaded=`/`dropped=`/`evicted=`/`recovered=` store traffic)
    /// and the durability counters (`persist: wal_appends=`/`snapshots=`).
    pub fn report(&self) -> String {
        format!(
            "jobs: submitted={} completed={} failed={} timeout={} cancelled={} updated={} | \
             graphs: loaded={} dropped={} evicted={} recovered={} | \
             persist: wal_appends={} snapshots={} | \
             repl: shipped={} applied={} acks={} lag={} | \
             matched={} edges={} | \
             latency mean={:.4}s p50≤{:.4}s p95≤{:.4}s p99≤{:.4}s",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.completed(),
            self.jobs_failed.load(Ordering::Relaxed),
            self.jobs_timed_out.load(Ordering::Relaxed),
            self.jobs_cancelled.load(Ordering::Relaxed),
            self.jobs_updated.load(Ordering::Relaxed),
            self.graphs_loaded.load(Ordering::Relaxed),
            self.graphs_dropped.load(Ordering::Relaxed),
            self.graphs_evicted.load(Ordering::Relaxed),
            self.graphs_recovered.load(Ordering::Relaxed),
            self.wal_appends.load(Ordering::Relaxed),
            self.snapshots_written.load(Ordering::Relaxed),
            self.repl_frames_shipped.load(Ordering::Relaxed),
            self.repl_frames_applied.load(Ordering::Relaxed),
            self.repl_acks.load(Ordering::Relaxed),
            self.repl_lag.load(Ordering::Relaxed),
            self.matched_total.load(Ordering::Relaxed),
            self.edges_processed.load(Ordering::Relaxed),
            self.mean_latency(),
            self.latency_quantile(0.50),
            self.latency_quantile(0.95),
            self.latency_quantile(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_monotone() {
        assert!(Metrics::bucket(0.000001) <= Metrics::bucket(0.001));
        assert!(Metrics::bucket(0.001) <= Metrics::bucket(1.0));
        assert!(Metrics::bucket(1e9) < N_BUCKETS);
    }

    #[test]
    fn bucket_bounds_match_documented_span() {
        // bucket i = [2^i, 2^{i+1}) µs; sub-µs clamps into bucket 0
        assert_eq!(Metrics::bucket(1e-9), 0, "sub-µs samples land in bucket 0");
        assert_eq!(Metrics::bucket(1.0e-6), 0);
        assert_eq!(Metrics::bucket(1.5e-6), 0);
        assert_eq!(Metrics::bucket(2.0e-6), 1);
        assert_eq!(Metrics::bucket(1.0), 19, "1 s = 10^6 µs → bucket floor(log2 1e6)");
        assert_eq!(Metrics::bucket(1e12), N_BUCKETS - 1, "overflow clamps to the last bucket");
    }

    #[test]
    fn quantile_zero_lands_on_first_nonempty_bucket() {
        let m = Metrics::new();
        m.observe_latency(1.0); // bucket 19 only; buckets 0..19 empty
        let q0 = m.latency_quantile(0.0);
        assert!(q0 >= 1.0, "q=0 must report the min sample's bucket, got {q0}");
        assert_eq!(m.latency_quantile(0.0), m.latency_quantile(1.0));
    }

    #[test]
    fn quantiles_and_mean() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(0.001);
        }
        for _ in 0..10 {
            m.observe_latency(1.0);
        }
        m.jobs_completed.store(100, Ordering::Relaxed);
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 < 0.01, "p50 {p50}");
        assert!(p99 >= 1.0, "p99 {p99}");
        let mean = m.mean_latency();
        assert!((0.05..0.3).contains(&mean), "mean {mean}");
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.5), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert!(m.report().contains("completed=0"));
    }

    #[test]
    fn report_exposes_every_failure_and_update_counter() {
        // regression for the "counted but not reported" gap: the wire
        // report must carry the timeout/cancelled split and the
        // incremental-subsystem counters verbatim
        let m = Metrics::new();
        m.jobs_timed_out.store(3, Ordering::Relaxed);
        m.jobs_cancelled.store(2, Ordering::Relaxed);
        m.jobs_updated.store(7, Ordering::Relaxed);
        m.graphs_loaded.store(4, Ordering::Relaxed);
        m.graphs_dropped.store(1, Ordering::Relaxed);
        m.graphs_evicted.store(5, Ordering::Relaxed);
        m.graphs_recovered.store(6, Ordering::Relaxed);
        m.wal_appends.store(11, Ordering::Relaxed);
        m.snapshots_written.store(9, Ordering::Relaxed);
        m.repl_frames_shipped.store(13, Ordering::Relaxed);
        m.repl_frames_applied.store(12, Ordering::Relaxed);
        m.repl_acks.store(8, Ordering::Relaxed);
        m.repl_lag.store(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("timeout=3"), "{r}");
        assert!(r.contains("cancelled=2"), "{r}");
        assert!(r.contains("updated=7"), "{r}");
        assert!(r.contains("loaded=4"), "{r}");
        assert!(r.contains("dropped=1"), "{r}");
        assert!(r.contains("evicted=5"), "{r}");
        assert!(r.contains("recovered=6"), "{r}");
        assert!(r.contains("wal_appends=11"), "{r}");
        assert!(r.contains("snapshots=9"), "{r}");
        assert!(r.contains("shipped=13"), "{r}");
        assert!(r.contains("applied=12"), "{r}");
        assert!(r.contains("acks=8"), "{r}");
        assert!(r.contains("lag=1"), "{r}");
    }
}
