//! Service metrics: counters and a log2-bucketed latency histogram,
//! lock-free on the hot path (atomics only), plus a per-spec aggregation
//! map (one brief leaf-mutex touch per completed job) and a
//! Prometheus-style text exposition behind the server's `METRICS` verb.

use crate::sanitize::lockorder::{self, LockClass};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

// Bucket `i` counts latencies in `[2^i, 2^{i+1})` µs; bucket 0 also
// absorbs every sub-µs sample and bucket 31 everything above. The real
// span is therefore 1 µs … 2^31 µs (≈ 36 min) — *not* the 2^-20 s …
// 2^11 s a symmetric-around-1s reading would suggest: `bucket()` clamps
// to ≥ 1 µs, so there are no sub-µs buckets.
const N_BUCKETS: usize = 32;

#[derive(Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub certify_failures: AtomicU64,
    /// jobs that tripped their deadline (also counted in `jobs_failed`)
    pub jobs_timed_out: AtomicU64,
    /// jobs abandoned via cancellation (also counted in `jobs_failed`)
    pub jobs_cancelled: AtomicU64,
    /// successful `UPDATE` jobs (also counted in `jobs_completed`)
    pub jobs_updated: AtomicU64,
    /// successful `LOAD` jobs (graphs installed into the store)
    pub graphs_loaded: AtomicU64,
    /// successful `DROP` jobs (graphs evicted from the store)
    pub graphs_dropped: AtomicU64,
    /// graphs reconstructed from the data dir (startup recovery plus
    /// transparent reloads of evicted names)
    pub graphs_recovered: AtomicU64,
    /// graphs pushed out of memory by the `--max-graphs` LRU cap
    pub graphs_evicted: AtomicU64,
    /// write-ahead-log frames fsync'd (LOAD/DROP markers and committed
    /// UPDATE records)
    pub wal_appends: AtomicU64,
    /// snapshot files written (LOAD bases, rebuild piggybacks, `SAVE`,
    /// eviction)
    pub snapshots_written: AtomicU64,
    /// replication events published to the follower stream (primary side:
    /// snapshots, update frames, and drop markers)
    pub repl_frames_shipped: AtomicU64,
    /// replication events applied from the stream (follower side)
    pub repl_frames_applied: AtomicU64,
    /// follower acknowledgements processed (primary side)
    pub repl_acks: AtomicU64,
    /// current replication lag in events: last published sequence minus
    /// highest acked sequence (primary side; gauge, not a counter)
    pub repl_lag: AtomicU64,
    pub edges_processed: AtomicU64,
    pub matched_total: AtomicU64,
    /// jobs whose end-to-end latency crossed the server's `--slow-ms`
    /// threshold (also counted in `jobs_completed`/`jobs_failed`; each
    /// one gets a compact trace summary on stderr)
    pub jobs_slow: AtomicU64,
    latency: [AtomicU64; N_BUCKETS],
    latency_sum_us: AtomicU64,
    /// unix ms at construction (0 for a bare `Default` — uptime reads 0
    /// then); the `bimatch_uptime_seconds` gauge and `HEALTH` use it
    start_unix_ms: AtomicU64,
    /// per-algorithm-spec aggregates, keyed by the wire spec name
    /// (`"hk"`, `"gpu:APFB-GPUBFS-WR-CT-FC"`, ...); a lock-order leaf
    /// touched once per completed job, never on the matcher hot path
    specs: Mutex<BTreeMap<String, SpecStats>>,
}

/// Aggregates for one algorithm spec, exposed as labeled `METRICS`
/// families (`bimatch_spec_*{spec="..."}`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SpecStats {
    pub jobs: u64,
    pub failed: u64,
    pub total_us: u64,
    pub device_cycles: u64,
}

impl Metrics {
    pub fn new() -> Self {
        let m = Self::default();
        m.start_unix_ms.store(crate::trace::unix_ms(), Ordering::Relaxed);
        m
    }

    /// Whole seconds since this process's metrics were created —
    /// effectively since serve/executor startup.
    pub fn uptime_seconds(&self) -> u64 {
        let start = self.start_unix_ms.load(Ordering::Relaxed);
        if start == 0 {
            return 0;
        }
        crate::trace::unix_ms().saturating_sub(start) / 1000
    }

    /// Bucket index for a latency: `floor(log2(µs))`, clamped into
    /// `[0, N_BUCKETS)` — sub-µs samples land in bucket 0, everything
    /// ≥ 2^31 µs in the last bucket.
    fn bucket(secs: f64) -> usize {
        let us = (secs * 1e6).max(1.0);
        (us.log2() as usize).min(N_BUCKETS - 1)
    }

    pub fn observe_latency(&self, secs: f64) {
        self.latency[Self::bucket(secs)].fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn completed(&self) -> u64 {
        self.jobs_completed.load(Ordering::Relaxed)
    }

    /// approximate quantile from the log2 histogram (upper bucket bound).
    /// `q = 0.0` returns the first *non-empty* bucket's bound (the
    /// minimum observed latency's bucket), not bucket 0's. `q` is
    /// clamped into `[0, 1]` (NaN reads as 0), so `q = 1.0` — and any
    /// overshoot — lands on the *last* non-empty bucket's bound instead
    /// of falling off the histogram into infinity.
    pub fn latency_quantile(&self, q: f64) -> f64 {
        // one consistent load per bucket: the target and the walk must
        // agree on the same counts or a concurrent observe_latency can
        // push `target` past what the walk sees
        let counts: [u64; N_BUCKETS] =
            std::array::from_fn(|i| self.latency[i].load(Ordering::Relaxed));
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        // q=0 would otherwise make target 0 and `seen >= 0` trivially
        // true at bucket 0 even when that bucket is empty; the upper
        // clamp guards float round-up past the population
        let target = (((total as f64) * q).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (2f64.powi(i as i32 + 1)) / 1e6; // upper bound, secs
            }
        }
        unreachable!("seen == total >= target after the last bucket")
    }

    pub fn mean_latency(&self) -> f64 {
        let n = self.completed();
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// Fold one finished job into its spec's aggregate family.
    pub fn record_spec(&self, spec: &str, secs: f64, ok: bool, device_cycles: u64) {
        let mut map = lockorder::lock(LockClass::SpecStats, &self.specs);
        let e = map.entry(spec.to_string()).or_default();
        e.jobs += 1;
        if !ok {
            e.failed += 1;
        }
        e.total_us += (secs * 1e6) as u64;
        e.device_cycles += device_cycles;
    }

    /// Snapshot of the per-spec aggregates (wire-name order).
    pub fn spec_stats(&self) -> Vec<(String, SpecStats)> {
        let map = lockorder::lock(LockClass::SpecStats, &self.specs);
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// The wire report behind the server's `STATS` verb. Every counter the
    /// executor maintains is on it — including the failure-mode split
    /// (`timeout=`/`cancelled=`, which are *also* inside `failed=`), the
    /// incremental-subsystem counters (`updated=` successful UPDATE jobs,
    /// `graphs loaded=`/`dropped=`/`evicted=`/`recovered=` store traffic)
    /// and the durability counters (`persist: wal_appends=`/`snapshots=`).
    pub fn report(&self) -> String {
        format!(
            "jobs: submitted={} completed={} failed={} timeout={} cancelled={} updated={} slow={} | \
             graphs: loaded={} dropped={} evicted={} recovered={} | \
             persist: wal_appends={} snapshots={} | \
             repl: shipped={} applied={} acks={} lag={} | \
             matched={} edges={} | \
             latency mean={:.4}s p50≤{:.4}s p95≤{:.4}s p99≤{:.4}s",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.completed(),
            self.jobs_failed.load(Ordering::Relaxed),
            self.jobs_timed_out.load(Ordering::Relaxed),
            self.jobs_cancelled.load(Ordering::Relaxed),
            self.jobs_updated.load(Ordering::Relaxed),
            self.jobs_slow.load(Ordering::Relaxed),
            self.graphs_loaded.load(Ordering::Relaxed),
            self.graphs_dropped.load(Ordering::Relaxed),
            self.graphs_evicted.load(Ordering::Relaxed),
            self.graphs_recovered.load(Ordering::Relaxed),
            self.wal_appends.load(Ordering::Relaxed),
            self.snapshots_written.load(Ordering::Relaxed),
            self.repl_frames_shipped.load(Ordering::Relaxed),
            self.repl_frames_applied.load(Ordering::Relaxed),
            self.repl_acks.load(Ordering::Relaxed),
            self.repl_lag.load(Ordering::Relaxed),
            self.matched_total.load(Ordering::Relaxed),
            self.edges_processed.load(Ordering::Relaxed),
            self.mean_latency(),
            self.latency_quantile(0.50),
            self.latency_quantile(0.95),
            self.latency_quantile(0.99),
        )
    }

    /// Prometheus text exposition (version 0.0.4) of every counter the
    /// executor maintains, the latency histogram (cumulative `le`
    /// buckets in seconds), and the per-spec label families. Per-graph
    /// families are appended by the executor, which owns the store.
    pub fn prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let counters: [(&str, &str, u64); 19] = [
            ("bimatch_jobs_submitted_total", "jobs accepted", self.jobs_submitted.load(Ordering::Relaxed)),
            ("bimatch_jobs_completed_total", "jobs finished ok", self.completed()),
            ("bimatch_jobs_failed_total", "jobs finished in error", self.jobs_failed.load(Ordering::Relaxed)),
            ("bimatch_jobs_timed_out_total", "jobs past deadline (also in failed)", self.jobs_timed_out.load(Ordering::Relaxed)),
            ("bimatch_jobs_cancelled_total", "jobs cancelled (also in failed)", self.jobs_cancelled.load(Ordering::Relaxed)),
            ("bimatch_jobs_updated_total", "successful UPDATE jobs", self.jobs_updated.load(Ordering::Relaxed)),
            ("bimatch_jobs_slow_total", "jobs past the --slow-ms threshold", self.jobs_slow.load(Ordering::Relaxed)),
            ("bimatch_certify_failures_total", "certification failures", self.certify_failures.load(Ordering::Relaxed)),
            ("bimatch_graphs_loaded_total", "graphs installed", self.graphs_loaded.load(Ordering::Relaxed)),
            ("bimatch_graphs_dropped_total", "graphs dropped", self.graphs_dropped.load(Ordering::Relaxed)),
            ("bimatch_graphs_evicted_total", "graphs evicted by the LRU cap", self.graphs_evicted.load(Ordering::Relaxed)),
            ("bimatch_graphs_recovered_total", "graphs reloaded from disk", self.graphs_recovered.load(Ordering::Relaxed)),
            ("bimatch_wal_appends_total", "WAL frames fsync'd", self.wal_appends.load(Ordering::Relaxed)),
            ("bimatch_snapshots_written_total", "snapshot files written", self.snapshots_written.load(Ordering::Relaxed)),
            ("bimatch_repl_frames_shipped_total", "replication events published", self.repl_frames_shipped.load(Ordering::Relaxed)),
            ("bimatch_repl_frames_applied_total", "replication events applied", self.repl_frames_applied.load(Ordering::Relaxed)),
            ("bimatch_repl_acks_total", "follower acks processed", self.repl_acks.load(Ordering::Relaxed)),
            ("bimatch_matched_total", "matched row-column pairs reported", self.matched_total.load(Ordering::Relaxed)),
            ("bimatch_edges_processed_total", "edges in completed jobs", self.edges_processed.load(Ordering::Relaxed)),
        ];
        for (name, help, v) in counters {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
        }
        out.push_str(&format!(
            "# HELP bimatch_repl_lag replication lag in events (published - acked)\n\
             # TYPE bimatch_repl_lag gauge\nbimatch_repl_lag {}\n",
            self.repl_lag.load(Ordering::Relaxed)
        ));
        out.push_str(&format!(
            "# HELP bimatch_uptime_seconds seconds since process startup\n\
             # TYPE bimatch_uptime_seconds gauge\nbimatch_uptime_seconds {}\n",
            self.uptime_seconds()
        ));

        // cumulative histogram: bucket i spans [2^i, 2^{i+1}) µs, so the
        // `le` bound of bucket i is 2^{i+1} µs expressed in seconds
        out.push_str(
            "# HELP bimatch_job_latency_seconds end-to-end job latency\n\
             # TYPE bimatch_job_latency_seconds histogram\n",
        );
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            cum += self.latency[i].load(Ordering::Relaxed);
            let le = 2f64.powi(i as i32 + 1) / 1e6;
            out.push_str(&format!("bimatch_job_latency_seconds_bucket{{le=\"{le:e}\"}} {cum}\n"));
        }
        out.push_str(&format!("bimatch_job_latency_seconds_bucket{{le=\"+Inf\"}} {cum}\n"));
        out.push_str(&format!(
            "bimatch_job_latency_seconds_sum {}\nbimatch_job_latency_seconds_count {cum}\n",
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / 1e6
        ));

        let specs = self.spec_stats();
        if !specs.is_empty() {
            out.push_str(
                "# HELP bimatch_spec_jobs_total jobs per algorithm spec\n\
                 # TYPE bimatch_spec_jobs_total counter\n",
            );
            for (spec, s) in &specs {
                out.push_str(&format!(
                    "bimatch_spec_jobs_total{{spec=\"{}\"}} {}\n",
                    prom_label_escape(spec),
                    s.jobs
                ));
            }
            out.push_str(
                "# HELP bimatch_spec_failed_total failed jobs per algorithm spec\n\
                 # TYPE bimatch_spec_failed_total counter\n",
            );
            for (spec, s) in &specs {
                out.push_str(&format!(
                    "bimatch_spec_failed_total{{spec=\"{}\"}} {}\n",
                    prom_label_escape(spec),
                    s.failed
                ));
            }
            out.push_str(
                "# HELP bimatch_spec_latency_seconds_sum total solve seconds per spec\n\
                 # TYPE bimatch_spec_latency_seconds_sum counter\n",
            );
            for (spec, s) in &specs {
                out.push_str(&format!(
                    "bimatch_spec_latency_seconds_sum{{spec=\"{}\"}} {}\n",
                    prom_label_escape(spec),
                    s.total_us as f64 / 1e6
                ));
            }
            out.push_str(
                "# HELP bimatch_spec_device_cycles_total modeled device cycles per spec\n\
                 # TYPE bimatch_spec_device_cycles_total counter\n",
            );
            for (spec, s) in &specs {
                out.push_str(&format!(
                    "bimatch_spec_device_cycles_total{{spec=\"{}\"}} {}\n",
                    prom_label_escape(spec),
                    s.device_cycles
                ));
            }
        }
        out
    }
}

/// Escape a value for a Prometheus label position: backslash, double
/// quote, and newline are the three characters the text format reserves.
pub fn prom_label_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_monotone() {
        assert!(Metrics::bucket(0.000001) <= Metrics::bucket(0.001));
        assert!(Metrics::bucket(0.001) <= Metrics::bucket(1.0));
        assert!(Metrics::bucket(1e9) < N_BUCKETS);
    }

    #[test]
    fn bucket_bounds_match_documented_span() {
        // bucket i = [2^i, 2^{i+1}) µs; sub-µs clamps into bucket 0
        assert_eq!(Metrics::bucket(1e-9), 0, "sub-µs samples land in bucket 0");
        assert_eq!(Metrics::bucket(1.0e-6), 0);
        assert_eq!(Metrics::bucket(1.5e-6), 0);
        assert_eq!(Metrics::bucket(2.0e-6), 1);
        assert_eq!(Metrics::bucket(1.0), 19, "1 s = 10^6 µs → bucket floor(log2 1e6)");
        assert_eq!(Metrics::bucket(1e12), N_BUCKETS - 1, "overflow clamps to the last bucket");
    }

    #[test]
    fn quantile_zero_lands_on_first_nonempty_bucket() {
        let m = Metrics::new();
        m.observe_latency(1.0); // bucket 19 only; buckets 0..19 empty
        let q0 = m.latency_quantile(0.0);
        assert!(q0 >= 1.0, "q=0 must report the min sample's bucket, got {q0}");
        assert_eq!(m.latency_quantile(0.0), m.latency_quantile(1.0));
    }

    #[test]
    fn quantiles_and_mean() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(0.001);
        }
        for _ in 0..10 {
            m.observe_latency(1.0);
        }
        m.jobs_completed.store(100, Ordering::Relaxed);
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 < 0.01, "p50 {p50}");
        assert!(p99 >= 1.0, "p99 {p99}");
        let mean = m.mean_latency();
        assert!((0.05..0.3).contains(&mean), "mean {mean}");
    }

    #[test]
    fn empty_metrics() {
        let m = Metrics::new();
        assert_eq!(m.latency_quantile(0.5), 0.0);
        assert_eq!(m.mean_latency(), 0.0);
        assert!(m.report().contains("completed=0"));
    }

    #[test]
    fn report_exposes_every_failure_and_update_counter() {
        // regression for the "counted but not reported" gap: the wire
        // report must carry the timeout/cancelled split and the
        // incremental-subsystem counters verbatim
        let m = Metrics::new();
        m.jobs_timed_out.store(3, Ordering::Relaxed);
        m.jobs_cancelled.store(2, Ordering::Relaxed);
        m.jobs_updated.store(7, Ordering::Relaxed);
        m.graphs_loaded.store(4, Ordering::Relaxed);
        m.graphs_dropped.store(1, Ordering::Relaxed);
        m.graphs_evicted.store(5, Ordering::Relaxed);
        m.graphs_recovered.store(6, Ordering::Relaxed);
        m.wal_appends.store(11, Ordering::Relaxed);
        m.snapshots_written.store(9, Ordering::Relaxed);
        m.repl_frames_shipped.store(13, Ordering::Relaxed);
        m.repl_frames_applied.store(12, Ordering::Relaxed);
        m.repl_acks.store(8, Ordering::Relaxed);
        m.repl_lag.store(1, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("timeout=3"), "{r}");
        assert!(r.contains("cancelled=2"), "{r}");
        assert!(r.contains("updated=7"), "{r}");
        assert!(r.contains("loaded=4"), "{r}");
        assert!(r.contains("dropped=1"), "{r}");
        assert!(r.contains("evicted=5"), "{r}");
        assert!(r.contains("recovered=6"), "{r}");
        assert!(r.contains("wal_appends=11"), "{r}");
        assert!(r.contains("snapshots=9"), "{r}");
        assert!(r.contains("shipped=13"), "{r}");
        assert!(r.contains("applied=12"), "{r}");
        assert!(r.contains("acks=8"), "{r}");
        assert!(r.contains("lag=1"), "{r}");
        m.jobs_slow.store(4, Ordering::Relaxed);
        assert!(m.report().contains("slow=4"), "{}", m.report());
    }

    #[test]
    fn quantile_one_is_the_max_bucket_bound_never_infinity() {
        let m = Metrics::new();
        m.observe_latency(3.0e-6); // bucket 1 = [2, 4) µs
        m.observe_latency(0.001); // bucket 9 = [512, 1024) µs
        // q=1 must land on the last non-empty bucket's upper bound
        let p100 = m.latency_quantile(1.0);
        assert_eq!(p100, 1024.0 / 1e6, "upper bound of [512, 1024) µs");
        // overshooting q must clamp, not fall off into infinity
        for q in [1.0000001, 2.0, f64::INFINITY, f64::NAN] {
            let v = m.latency_quantile(q);
            assert!(v.is_finite(), "q={q} gave {v}");
        }
        assert_eq!(m.latency_quantile(2.0), p100);
        // NaN reads as q=0: the first non-empty bucket
        assert_eq!(m.latency_quantile(f64::NAN), m.latency_quantile(0.0));
        assert_eq!(m.latency_quantile(0.0), 4.0 / 1e6, "upper bound of [2, 4) µs");
    }

    #[test]
    fn quantile_bounds_follow_the_bucket_spec() {
        // a sample at 2^i µs sits in bucket i, so every quantile of a
        // single-sample histogram reports exactly 2^{i+1} µs
        for i in [0, 5, 19, 25] {
            let m = Metrics::new();
            m.observe_latency(2f64.powi(i) / 1e6);
            for q in [0.0, 0.5, 0.99, 1.0] {
                assert_eq!(m.latency_quantile(q), 2f64.powi(i + 1) / 1e6, "i={i} q={q}");
            }
        }
    }

    #[test]
    fn record_spec_aggregates_per_wire_name() {
        let m = Metrics::new();
        m.record_spec("hk", 0.002, true, 0);
        m.record_spec("hk", 0.004, false, 0);
        m.record_spec("gpu:APFB-GPUBFS-WR-CT-FC", 0.1, true, 12345);
        let specs = m.spec_stats();
        assert_eq!(specs.len(), 2);
        // BTreeMap order: "gpu:..." < "hk"
        assert_eq!(specs[0].0, "gpu:APFB-GPUBFS-WR-CT-FC");
        assert_eq!(specs[0].1.device_cycles, 12345);
        assert_eq!(specs[1].0, "hk");
        assert_eq!(specs[1].1, SpecStats { jobs: 2, failed: 1, total_us: 6000, device_cycles: 0 });
    }

    #[test]
    fn prometheus_exposition_is_wellformed() {
        let m = Metrics::new();
        m.jobs_submitted.store(3, Ordering::Relaxed);
        m.jobs_completed.store(2, Ordering::Relaxed);
        m.observe_latency(0.001);
        m.observe_latency(0.5);
        m.record_spec("p-dbfs@4", 0.001, true, 0);
        let text = m.prometheus();
        assert!(text.contains("# TYPE bimatch_jobs_submitted_total counter"), "{text}");
        assert!(text.contains("bimatch_jobs_submitted_total 3"), "{text}");
        assert!(text.contains("# TYPE bimatch_repl_lag gauge"), "{text}");
        assert!(text.contains("# TYPE bimatch_job_latency_seconds histogram"), "{text}");
        assert!(text.contains("bimatch_job_latency_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("bimatch_job_latency_seconds_count 2"), "{text}");
        assert!(text.contains("bimatch_spec_jobs_total{spec=\"p-dbfs@4\"} 1"), "{text}");
        // cumulative le buckets never decrease
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.starts_with("bimatch_job_latency_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "non-monotone bucket line: {line}");
            prev = v;
        }
        // every non-comment line is `name{labels} value` or `name value`
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert_eq!(line.split(' ').count(), 2, "malformed line: {line}");
        }
    }

    #[test]
    fn label_escaping_covers_the_reserved_characters() {
        assert_eq!(prom_label_escape("plain-name_1:ok"), "plain-name_1:ok");
        assert_eq!(prom_label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
