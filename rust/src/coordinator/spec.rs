//! Typed algorithm specification — the coordinator's dispatch currency.
//!
//! Every matcher the registry can build is named by an [`AlgoSpec`];
//! the stringly registry names ("hk", "p-dbfs", "gpu:APFB-GPUBFS-WR-CT-FC",
//! "xla:apfb-full") remain the stable wire/CLI format via `FromStr` and
//! `Display`, which round-trip every registry name. Configuration edits
//! that used to be string surgery (rewriting the "-FC" suffix to change
//! the frontier mode) are typed field edits here ([`AlgoSpec::set_frontier`]).
//!
//! Extensions over the legacy names: multicore specs can carry an explicit
//! thread count on the wire as `p-hk@8` / `p-pfp@4` / `p-dbfs@2`
//! (omitted = the worker default), and `gpu` stays the alias for the
//! paper's best variant.

use crate::gpu::{FrontierMode, GpuConfig};
use std::fmt;
use std::str::FromStr;

/// Sequential baselines (see `crate::seq`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SeqKind {
    Hk,
    Hkdw,
    Pfp,
    Dfs,
    Bfs,
    Pr,
}

impl SeqKind {
    pub const ALL: [SeqKind; 6] =
        [SeqKind::Hk, SeqKind::Hkdw, SeqKind::Pfp, SeqKind::Dfs, SeqKind::Bfs, SeqKind::Pr];

    pub fn name(&self) -> &'static str {
        match self {
            SeqKind::Hk => "hk",
            SeqKind::Hkdw => "hkdw",
            SeqKind::Pfp => "pfp",
            SeqKind::Dfs => "dfs",
            SeqKind::Bfs => "bfs",
            SeqKind::Pr => "pr",
        }
    }

    fn from_name(s: &str) -> Option<SeqKind> {
        SeqKind::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// Multicore baselines (see `crate::multicore`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MulticoreKind {
    Hk,
    Pfp,
    Dbfs,
}

impl MulticoreKind {
    pub const ALL: [MulticoreKind; 3] =
        [MulticoreKind::Hk, MulticoreKind::Pfp, MulticoreKind::Dbfs];

    pub fn name(&self) -> &'static str {
        match self {
            MulticoreKind::Hk => "p-hk",
            MulticoreKind::Pfp => "p-pfp",
            MulticoreKind::Dbfs => "p-dbfs",
        }
    }

    fn from_name(s: &str) -> Option<MulticoreKind> {
        MulticoreKind::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// XLA-backed matchers (see `crate::gpu::xla_backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum XlaKind {
    ApfbFull,
    BfsLevelHybrid,
}

impl XlaKind {
    pub const ALL: [XlaKind; 2] = [XlaKind::ApfbFull, XlaKind::BfsLevelHybrid];

    pub fn name(&self) -> &'static str {
        match self {
            XlaKind::ApfbFull => "apfb-full",
            XlaKind::BfsLevelHybrid => "bfs-level-hybrid",
        }
    }

    fn from_name(s: &str) -> Option<XlaKind> {
        XlaKind::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// A fully specified matcher. `Display`/`FromStr` are the wire format;
/// `registry::build` turns a spec into a ready-to-run
/// `Box<dyn MatchingAlgorithm>`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoSpec {
    Seq(SeqKind),
    /// `threads: None` = the worker default (`BIMATCH_THREADS` or the
    /// machine's available parallelism), resolved at build time.
    Multicore { kind: MulticoreKind, threads: Option<usize> },
    Gpu(GpuConfig),
    /// A GPU variant executed across `shards` simulated devices with the
    /// modeled interconnect (`crate::shard`); wire format
    /// `shard{K}:gpu:{variant}` with `K >= 1`.
    Sharded { inner: GpuConfig, shards: usize },
    Xla(XlaKind),
}

impl AlgoSpec {
    /// The typed replacement for the old "-FC"-suffix string surgery:
    /// set the frontier mode of a GPU (or sharded-GPU) spec; a no-op on
    /// CPU/XLA specs.
    pub fn set_frontier(&mut self, mode: FrontierMode) {
        match self {
            AlgoSpec::Gpu(cfg) | AlgoSpec::Sharded { inner: cfg, .. } => cfg.frontier = mode,
            _ => {}
        }
    }

    /// Builder-style [`AlgoSpec::set_frontier`].
    pub fn with_frontier(mut self, mode: FrontierMode) -> Self {
        self.set_frontier(mode);
        self
    }

    /// True for specs that execute on the simulated device — plain GPU
    /// variants and their sharded wrappers — i.e. the specs whose
    /// frontier mode [`AlgoSpec::set_frontier`] can edit.
    pub fn is_gpu(&self) -> bool {
        matches!(self, AlgoSpec::Gpu(_) | AlgoSpec::Sharded { .. })
    }

    pub fn is_xla(&self) -> bool {
        matches!(self, AlgoSpec::Xla(_))
    }
}

impl fmt::Display for AlgoSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AlgoSpec::Seq(k) => f.write_str(k.name()),
            AlgoSpec::Multicore { kind, threads: None } => f.write_str(kind.name()),
            AlgoSpec::Multicore { kind, threads: Some(n) } => write!(f, "{}@{n}", kind.name()),
            AlgoSpec::Gpu(cfg) => write!(f, "gpu:{}", cfg.name()),
            AlgoSpec::Sharded { inner, shards } => write!(f, "shard{shards}:gpu:{}", inner.name()),
            AlgoSpec::Xla(k) => write!(f, "xla:{}", k.name()),
        }
    }
}

impl FromStr for AlgoSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        if s == "gpu" {
            // registry alias for the paper's overall winner
            return Ok(AlgoSpec::Gpu(GpuConfig::default()));
        }
        if let Some(v) = s.strip_prefix("gpu:") {
            return GpuConfig::from_name(v)
                .map(AlgoSpec::Gpu)
                .ok_or_else(|| format!("unknown gpu variant {v:?} (see `bimatch algos`)"));
        }
        if let Some(rest) = s.strip_prefix("shard") {
            // shard{K}:gpu:{variant} — K >= 1, inner spec must be a gpu
            // variant (sharding CPU/XLA matchers is not a thing)
            let (count, inner) = rest
                .split_once(':')
                .ok_or_else(|| format!("expected shard<K>:gpu:<variant>, got {s:?}"))?;
            let shards: usize = count
                .parse()
                .map_err(|_| format!("bad shard count {count:?} in {s:?}"))?;
            if shards == 0 {
                return Err(format!("shard count must be >= 1 in {s:?}"));
            }
            if inner == "gpu" {
                // same alias as the unsharded "gpu": the paper's winner
                return Ok(AlgoSpec::Sharded { inner: GpuConfig::default(), shards });
            }
            let v = inner.strip_prefix("gpu:").ok_or_else(|| {
                format!("sharded execution wraps gpu variants only (shard<K>:gpu:<variant>), got {s:?}")
            })?;
            return GpuConfig::from_name(v)
                .map(|cfg| AlgoSpec::Sharded { inner: cfg, shards })
                .ok_or_else(|| format!("unknown gpu variant {v:?} (see `bimatch algos`)"));
        }
        if let Some(v) = s.strip_prefix("xla:") {
            return XlaKind::from_name(v)
                .map(AlgoSpec::Xla)
                .ok_or_else(|| format!("unknown xla program {v:?} (see `bimatch algos`)"));
        }
        let (base, threads) = match s.split_once('@') {
            Some((base, t)) => {
                let n: usize =
                    t.parse().map_err(|_| format!("bad thread count {t:?} in {s:?}"))?;
                if n == 0 {
                    return Err(format!("thread count must be >= 1 in {s:?}"));
                }
                (base, Some(n))
            }
            None => (s, None),
        };
        if let Some(kind) = MulticoreKind::from_name(base) {
            return Ok(AlgoSpec::Multicore { kind, threads });
        }
        if threads.is_some() {
            return Err(format!(
                "{base:?} is not a multicore algorithm; \"@threads\" applies to p-hk/p-pfp/p-dbfs"
            ));
        }
        SeqKind::from_name(s)
            .map(AlgoSpec::Seq)
            .ok_or_else(|| format!("unknown algorithm {s:?} (see `bimatch algos`)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry;

    /// Satellite: the redesign preserves the wire format — parse∘print is
    /// the identity on every registry name, and print∘parse is the
    /// identity on every spec.
    #[test]
    fn prop_every_registry_name_roundtrips() {
        for name in registry::all_names() {
            let spec: AlgoSpec = name.parse().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.to_string(), name, "Display must reproduce the registry name");
            let again: AlgoSpec = spec.to_string().parse().unwrap();
            assert_eq!(again, spec, "{name}: from_str(to_string(spec)) != spec");
        }
    }

    /// Same property over the full spec space, including explicit thread
    /// counts and every GPU variant (not just the registry's defaults).
    #[test]
    fn prop_every_spec_roundtrips_through_its_name() {
        let mut specs: Vec<AlgoSpec> = Vec::new();
        specs.extend(SeqKind::ALL.into_iter().map(AlgoSpec::Seq));
        for kind in MulticoreKind::ALL {
            for threads in [None, Some(1), Some(2), Some(7), Some(64)] {
                specs.push(AlgoSpec::Multicore { kind, threads });
            }
        }
        specs.extend(GpuConfig::all_variants_with_frontier().into_iter().map(AlgoSpec::Gpu));
        for inner in GpuConfig::all_variants_with_frontier() {
            for shards in [1usize, 2, 3, 4, 8, 17] {
                specs.push(AlgoSpec::Sharded { inner, shards });
            }
        }
        specs.extend(XlaKind::ALL.into_iter().map(AlgoSpec::Xla));
        assert!(specs.len() > 30);
        for spec in specs {
            let parsed: AlgoSpec = spec.to_string().parse().unwrap_or_else(|e| {
                panic!("{spec} did not parse back: {e}");
            });
            assert_eq!(parsed, spec);
        }
    }

    #[test]
    fn malformed_names_rejected() {
        for bad in [
            "",
            "nope",
            "gpu:",
            "gpu:NOPE",
            "gpu:NOPE-FC",
            "gpu:APFB-GPUBFS-WR-CT-FC-FC",
            "xla:",
            "xla:nope",
            "p-hk@0",
            "p-hk@x",
            "p-hk@",
            "p-hk@-3",
            "hk@4",
            "p-nope@4",
            "shard",
            "shard4",
            "shard0:gpu:APFB-GPUBFS-WR-CT",
            "shardx:gpu:APFB-GPUBFS-WR-CT",
            "shard4:hk",
            "shard4:xla:apfb-full",
            "shard4:gpu:NOPE",
            "shard4:",
        ] {
            assert!(bad.parse::<AlgoSpec>().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn gpu_alias_is_paper_best() {
        let spec: AlgoSpec = "gpu".parse().unwrap();
        assert_eq!(spec, AlgoSpec::Gpu(GpuConfig::default()));
        assert_eq!(spec.to_string(), "gpu:APFB-GPUBFS-WR-CT");
    }

    #[test]
    fn multicore_thread_counts_on_the_wire() {
        let spec: AlgoSpec = "p-dbfs@8".parse().unwrap();
        assert_eq!(spec, AlgoSpec::Multicore { kind: MulticoreKind::Dbfs, threads: Some(8) });
        assert_eq!(spec.to_string(), "p-dbfs@8");
        let spec: AlgoSpec = "p-dbfs".parse().unwrap();
        assert_eq!(spec, AlgoSpec::Multicore { kind: MulticoreKind::Dbfs, threads: None });
    }

    #[test]
    fn frontier_edit_is_typed_not_string_surgery() {
        let mut spec: AlgoSpec = "gpu:APFB-GPUBFS-WR-CT".parse().unwrap();
        spec.set_frontier(FrontierMode::Compacted);
        assert_eq!(spec.to_string(), "gpu:APFB-GPUBFS-WR-CT-FC");
        let spec = spec.with_frontier(FrontierMode::FullScan);
        assert_eq!(spec.to_string(), "gpu:APFB-GPUBFS-WR-CT");
        // no-op on CPU specs
        let mut cpu: AlgoSpec = "pfp".parse().unwrap();
        cpu.set_frontier(FrontierMode::Compacted);
        assert_eq!(cpu.to_string(), "pfp");
        assert!(!cpu.is_gpu());
        assert!("xla:apfb-full".parse::<AlgoSpec>().unwrap().is_xla());
        // the edit reaches through a sharded wrapper to its inner config
        let mut sharded: AlgoSpec = "shard4:gpu:APFB-GPUBFS-WR-CT".parse().unwrap();
        sharded.set_frontier(FrontierMode::Compacted);
        assert_eq!(sharded.to_string(), "shard4:gpu:APFB-GPUBFS-WR-CT-FC");
    }

    #[test]
    fn sharded_specs_parse_and_roundtrip() {
        let spec: AlgoSpec = "shard4:gpu:APFB-GPUBFS-WR-CT-FC".parse().unwrap();
        let AlgoSpec::Sharded { inner, shards } = spec else {
            panic!("expected a sharded spec, got {spec:?}");
        };
        assert_eq!(shards, 4);
        assert_eq!(inner.name(), "APFB-GPUBFS-WR-CT-FC");
        assert_eq!(spec.to_string(), "shard4:gpu:APFB-GPUBFS-WR-CT-FC");
        // the bare-gpu alias works under sharding too
        let alias: AlgoSpec = "shard2:gpu".parse().unwrap();
        assert_eq!(alias, AlgoSpec::Sharded { inner: crate::gpu::GpuConfig::default(), shards: 2 });
        assert_eq!(alias.to_string(), "shard2:gpu:APFB-GPUBFS-WR-CT");
        // shard1 is legal: the degenerate single-device run
        assert!("shard1:gpu".parse::<AlgoSpec>().is_ok());
    }
}
