//! Name → algorithm registry: every matcher in the library (sequential,
//! multicore, the 8 GPU variants plus their frontier-compacted "-FC"
//! twins — worklist-driven BFS sweeps *and* endpoint-list ALTERNATE, the
//! router's default GPU pick — XLA-backed) constructible from its stable
//! string name. The CLI, router, server protocol, and bench harness all
//! resolve algorithms through here.

use crate::gpu::{GpuConfig, GpuMatcher};
use crate::matching::algo::MatchingAlgorithm;
use crate::multicore::{PDbfs, PHk, PPfp};
use crate::runtime::Engine;
use crate::seq;
use crate::util::pool::default_threads;
use std::sync::Arc;

/// All registry names (GPU variants use the paper's naming).
pub fn all_names() -> Vec<String> {
    let mut names: Vec<String> = vec![
        "hk".into(),
        "hkdw".into(),
        "pfp".into(),
        "dfs".into(),
        "bfs".into(),
        "pr".into(),
        "p-hk".into(),
        "p-pfp".into(),
        "p-dbfs".into(),
        "xla:apfb-full".into(),
        "xla:bfs-level-hybrid".into(),
    ];
    // the eight paper variants plus their frontier-compacted "-FC" twins
    for cfg in GpuConfig::all_variants_with_frontier() {
        names.push(format!("gpu:{}", cfg.name()));
    }
    names
}

/// Build an algorithm by name. `engine` is required for "xla:*" names.
pub fn build(name: &str, engine: Option<Arc<Engine>>) -> Option<Box<dyn MatchingAlgorithm>> {
    let nt = default_threads();
    Some(match name {
        "hk" => Box::new(seq::Hk),
        "hkdw" => Box::new(seq::Hkdw),
        "pfp" => Box::new(seq::Pfp),
        "dfs" => Box::new(seq::DfsLookahead),
        "bfs" => Box::new(seq::BfsSimple),
        "pr" => Box::new(seq::PushRelabel),
        "p-hk" => Box::new(PHk { nthreads: nt }),
        "p-pfp" => Box::new(PPfp { nthreads: nt }),
        "p-dbfs" => Box::new(PDbfs { nthreads: nt }),
        "gpu" => Box::new(GpuMatcher::default()), // paper's best variant
        "xla:apfb-full" => {
            Box::new(crate::gpu::xla_backend::XlaApfbMatcher::new(engine?))
        }
        "xla:bfs-level-hybrid" => {
            Box::new(crate::gpu::xla_backend::XlaHybridMatcher::new(engine?))
        }
        _ => {
            let variant = name.strip_prefix("gpu:")?;
            let cfg = GpuConfig::from_name(variant)?;
            Box::new(GpuMatcher::new(cfg))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::Matching;

    #[test]
    fn every_registered_name_builds_and_runs() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2), (0, 1)]);
        for name in all_names() {
            if name.starts_with("xla:") {
                // requires an engine + artifacts; covered in rust/tests/
                assert!(build(&name, None).is_none());
                continue;
            }
            let algo = build(&name, None).unwrap_or_else(|| panic!("{name} not buildable"));
            let r = algo.run(&g, Matching::empty(3, 3));
            r.matching.certify(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.matching.cardinality(), 3, "{name}");
        }
    }

    #[test]
    fn unknown_names_rejected() {
        assert!(build("nope", None).is_none());
        assert!(build("gpu:NOPE", None).is_none());
        assert!(build("gpu:NOPE-FC", None).is_none());
    }

    #[test]
    fn frontier_variants_registered_and_buildable() {
        let names = all_names();
        assert!(names.iter().any(|n| n == "gpu:APFB-GPUBFS-WR-CT-FC"));
        assert_eq!(names.iter().filter(|n| n.starts_with("gpu:")).count(), 16);
        let a = build("gpu:APFB-GPUBFS-WR-CT-FC", None).unwrap();
        assert_eq!(a.name(), "gpu:APFB-GPUBFS-WR-CT-FC");
    }

    #[test]
    fn shorthand_gpu_is_paper_best() {
        let a = build("gpu", None).unwrap();
        assert_eq!(a.name(), "gpu:APFB-GPUBFS-WR-CT");
    }
}
