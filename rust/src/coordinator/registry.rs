//! [`AlgoSpec`] → algorithm registry: every matcher in the library
//! (sequential, multicore, the 8 GPU variants plus their
//! frontier-compacted "-FC" twins, XLA-backed) constructible from its
//! typed spec — and hence from its stable string name via
//! `AlgoSpec::from_str`. The CLI, router, server protocol, and bench
//! harness all resolve algorithms through here.
//!
//! Registry-name stability is an enforced invariant: `all_names()` must
//! match the checked-in `rust/registry-names.txt` golden file (unit test
//! below; CI additionally diffs the file against `bimatch --list-algos`).

use super::spec::{AlgoSpec, MulticoreKind, SeqKind, XlaKind};
use crate::gpu::{GpuConfig, GpuMatcher};
use crate::matching::algo::MatchingAlgorithm;
use crate::multicore::{PDbfs, PHk, PPfp};
use crate::runtime::Engine;
use crate::seq;
use crate::util::pool::default_threads;
use std::sync::Arc;

/// Every registered spec (GPU variants use the paper's naming).
pub fn all_specs() -> Vec<AlgoSpec> {
    let mut specs: Vec<AlgoSpec> = SeqKind::ALL.into_iter().map(AlgoSpec::Seq).collect();
    specs.extend(
        MulticoreKind::ALL
            .into_iter()
            .map(|kind| AlgoSpec::Multicore { kind, threads: None }),
    );
    specs.extend(XlaKind::ALL.into_iter().map(AlgoSpec::Xla));
    // the eight paper variants plus their frontier-compacted "-FC" twins
    specs.extend(GpuConfig::all_variants_with_frontier().into_iter().map(AlgoSpec::Gpu));
    // sharded execution of the router's default GPU pick (the compacted
    // paper winner) at the bench ablation's shard counts; other K and
    // inner variants parse fine (`shard<K>:gpu:<variant>`) without being
    // registered
    specs.extend(
        [2usize, 4, 8]
            .into_iter()
            .map(|shards| AlgoSpec::Sharded { inner: GpuConfig::default().compacted(), shards }),
    );
    specs
}

/// All registry names — `all_specs()` through the stable wire format.
pub fn all_names() -> Vec<String> {
    all_specs().iter().map(|s| s.to_string()).collect()
}

/// Build an algorithm from its spec. Returns `None` only for `Xla(_)`
/// specs without an engine (artifacts absent).
pub fn build(spec: &AlgoSpec, engine: Option<Arc<Engine>>) -> Option<Box<dyn MatchingAlgorithm>> {
    Some(match *spec {
        AlgoSpec::Seq(SeqKind::Hk) => Box::new(seq::Hk),
        AlgoSpec::Seq(SeqKind::Hkdw) => Box::new(seq::Hkdw),
        AlgoSpec::Seq(SeqKind::Pfp) => Box::new(seq::Pfp),
        AlgoSpec::Seq(SeqKind::Dfs) => Box::new(seq::DfsLookahead),
        AlgoSpec::Seq(SeqKind::Bfs) => Box::new(seq::BfsSimple),
        AlgoSpec::Seq(SeqKind::Pr) => Box::new(seq::PushRelabel),
        AlgoSpec::Multicore { kind, threads } => {
            let nthreads = threads.unwrap_or_else(default_threads);
            match kind {
                MulticoreKind::Hk => Box::new(PHk { nthreads }),
                MulticoreKind::Pfp => Box::new(PPfp { nthreads }),
                MulticoreKind::Dbfs => Box::new(PDbfs { nthreads }),
            }
        }
        AlgoSpec::Gpu(cfg) => Box::new(GpuMatcher::new(cfg)),
        AlgoSpec::Sharded { inner, shards } => {
            Box::new(crate::shard::ShardedGpuMatcher::new(inner, shards))
        }
        AlgoSpec::Xla(XlaKind::ApfbFull) => {
            Box::new(crate::gpu::xla_backend::XlaApfbMatcher::new(engine?))
        }
        AlgoSpec::Xla(XlaKind::BfsLevelHybrid) => {
            Box::new(crate::gpu::xla_backend::XlaHybridMatcher::new(engine?))
        }
    })
}

/// The operator-facing message for a spec that parses but cannot build —
/// shared by every surface (CLI, server, service) so the guidance never
/// drifts between them.
pub fn unavailable_msg(spec: &AlgoSpec) -> String {
    format!("{spec} requires an XLA engine (run `make artifacts`)")
}

/// Parse-and-build convenience for callers holding a wire name (CLI,
/// harness). The error distinguishes "no such algorithm" from "algorithm
/// known but unavailable" (xla without artifacts).
pub fn build_named(
    name: &str,
    engine: Option<Arc<Engine>>,
) -> Result<Box<dyn MatchingAlgorithm>, String> {
    let spec: AlgoSpec = name.parse()?;
    build(&spec, engine).ok_or_else(|| unavailable_msg(&spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::from_edges;
    use crate::matching::Matching;

    #[test]
    fn every_registered_spec_builds_and_runs() {
        let g = from_edges(3, 3, &[(0, 0), (1, 1), (2, 2), (0, 1)]);
        for spec in all_specs() {
            if spec.is_xla() {
                // requires an engine + artifacts; covered in rust/tests/
                assert!(build(&spec, None).is_none());
                continue;
            }
            let algo = build(&spec, None).unwrap_or_else(|| panic!("{spec} not buildable"));
            let r = algo.run_detached(&g, Matching::empty(3, 3));
            r.matching.certify(&g).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(r.matching.cardinality(), 3, "{spec}");
            assert!(r.is_complete(), "{spec}");
        }
    }

    #[test]
    fn build_named_distinguishes_unknown_from_unavailable() {
        assert!(build_named("hk", None).is_ok());
        let unknown = build_named("nope", None).unwrap_err();
        assert!(unknown.contains("unknown algorithm"), "{unknown}");
        let unavailable = build_named("xla:apfb-full", None).unwrap_err();
        assert!(unavailable.contains("XLA engine"), "{unavailable}");
    }

    #[test]
    fn frontier_variants_registered_and_buildable() {
        let names = all_names();
        assert!(names.iter().any(|n| n == "gpu:APFB-GPUBFS-WR-CT-FC"));
        assert_eq!(names.iter().filter(|n| n.starts_with("gpu:")).count(), 16);
        let a = build_named("gpu:APFB-GPUBFS-WR-CT-FC", None).unwrap();
        assert_eq!(a.name(), "gpu:APFB-GPUBFS-WR-CT-FC");
    }

    #[test]
    fn sharded_variants_registered_and_buildable() {
        let names = all_names();
        for k in [2, 4, 8] {
            let name = format!("shard{k}:gpu:APFB-GPUBFS-WR-CT-FC");
            assert!(names.contains(&name), "{name} must be registered");
            let a = build_named(&name, None).unwrap();
            assert_eq!(a.name(), name);
        }
        assert_eq!(names.iter().filter(|n| n.starts_with("shard")).count(), 3);
        // unregistered shard counts / inner variants still build by name
        let a = build_named("shard3:gpu:APsB-GPUBFS-CT", None).unwrap();
        assert_eq!(a.name(), "shard3:gpu:APsB-GPUBFS-CT");
    }

    #[test]
    fn shorthand_gpu_is_paper_best() {
        let a = build_named("gpu", None).unwrap();
        assert_eq!(a.name(), "gpu:APFB-GPUBFS-WR-CT");
    }

    #[test]
    fn explicit_thread_count_respected() {
        let a = build_named("p-dbfs@3", None).unwrap();
        assert_eq!(a.name(), "p-dbfs@3");
    }

    /// The back-compat contract of the AlgoSpec redesign: the registry
    /// names are frozen in a golden file. Regenerate deliberately with
    /// `cargo run --release -- --list-algos > registry-names.txt` when a
    /// PR intentionally adds algorithms; CI diffs the same file against
    /// the binary's output.
    #[test]
    fn registry_names_match_golden_file() {
        let golden = include_str!("../../registry-names.txt");
        let actual = all_names().join("\n") + "\n";
        assert_eq!(actual, golden, "registry names drifted from registry-names.txt");
    }
}
