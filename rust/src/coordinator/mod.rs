//! The L3 coordinator: a matching *service* around the algorithm library —
//! job queue with backpressure, worker pool, feature-based algorithm
//! routing (the paper's "GPU wins except banded originals" finding as
//! policy), metrics, a server-side graph store for the incremental
//! (online-matching) verbs, and a TCP line-protocol front end.

pub mod exec;
pub mod job;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod router;
pub mod server;
pub mod service;
pub mod spec;
pub mod store;

pub use exec::Executor;
pub use job::{AlgoChoice, GraphSource, JobError, JobOp, MatchJob, MatchOutcome, UpdateStats};
pub use metrics::Metrics;
pub use server::{Server, ServerCfg};
pub use service::{Service, ServiceConfig};
pub use spec::{AlgoSpec, MulticoreKind, SeqKind, XlaKind};
pub use store::GraphStore;
