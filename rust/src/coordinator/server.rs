//! Line-protocol TCP front end for the matching service (no tokio offline;
//! std::net + one thread per connection, bounded by the accept loop).
//!
//! Protocol (one request per line, one reply per line):
//!
//! ```text
//! MATCH family=<name> n=<int> seed=<int> [permute=0|1] [algo=<name>]
//!       [init=<name>] [timeout_ms=<int>]
//! MATCH mtx=<path> [algo=<name>] [timeout_ms=<int>]
//! MATCH name=<graph> [algo=<name>] [timeout_ms=<int>]
//! LOAD  name=<graph> (family=… n=… [seed=…] [permute=0|1] | mtx=<path>)
//! UPDATE name=<graph> [add=r:c,r:c,…] [del=r:c,…] [addcols=r;r|r|…]
//!        [addrows=c;c|c|…] [algo=<name>] [timeout_ms=<int>]
//! DROP  name=<graph>
//! SAVE  name=<graph>          durable snapshot + WAL compaction now
//! ALGOS                       → ALGOS <name> <name> ...
//! GRAPHS                      → GRAPHS <name> <name> ...
//! STATS                       → STATS <metrics report>
//! QUIT
//! ```
//!
//! `algo=` accepts any registry name (`AlgoSpec` wire format, including
//! `p-hk@<threads>`); malformed names are rejected before execution.
//! `timeout_ms=` sets a deadline over the whole job (load + init +
//! matching — and for `UPDATE`, apply + repair); a tripped job replies
//! `ERR timeout: ...` — a distinct failure, never a silently suboptimal
//! matching.
//!
//! The incremental verbs hold graphs server-side
//! ([`super::store::GraphStore`]): `LOAD` installs a graph under a name,
//! `UPDATE` ships a delta batch (`add`/`del` are comma-separated
//! `row:col` edges, `addcols`/`addrows` append columns/rows as
//! `|`-separated `;`-lists of neighbor ids — clauses apply in the
//! canonical order `addrows, addcols, add, del`, so an edge clause may
//! reference a vertex appended by the same request) and repairs the
//! maintained matching via seeded augmentation, and `MATCH name=…`
//! re-serves the cached maximum (warm start — one quiet phase). The
//! `STATS` report covers them (`updated=`, `graphs:
//! loaded=/dropped=/evicted=/recovered=`) next to the failure split
//! (`timeout=`, `cancelled=`) and the durability counters (`persist:
//! wal_appends=/snapshots=`).
//!
//! When the server is bound with a data dir ([`Server::bind_with`]),
//! graphs survive restarts: `LOAD`s and `UPDATE`s are persisted (WAL +
//! snapshots, fsync'd before the OK reply), startup recovery replays the
//! log and repairs each matching, and `SAVE name=…` forces a snapshot +
//! log compaction on demand. See `crate::persist` for the guarantees.
//!
//! Replies:
//! `OK id=<id> algo=<name> nr=.. nc=.. edges=.. card=.. certified=0|1
//!  t_load=.. t_match=.. frontier_peak=.. endpoints=.. devpar_cycles=..`
//! or `ERR <message>`. The last three OK fields expose the
//! frontier-compaction counters (`RunStats::{frontier_peak,
//! endpoints_total, device_parallel_cycles}`) so remote clients can
//! observe compaction behaviour; all three are 0 for CPU algorithms and
//! for FullScan GPU runs. `LOAD`/`DROP`/`SAVE` reply
//! `OK id=<id> name=<graph> nr=.. nc=.. edges=..` /
//! `OK id=<id> name=<graph> dropped=1` /
//! `OK id=<id> name=<graph> saved=1`; `UPDATE` appends
//! `inserted= deleted= cols_added= rows_added= rejected= seeds= dropped=
//! joined= rebuilt=` to the standard OK fields.

use super::exec::Executor;
use super::job::{GraphSource, MatchJob, MatchOutcome};
use super::metrics::Metrics;
use super::registry;
use super::spec::AlgoSpec;
use crate::dynamic::DeltaBatch;
use crate::graph::gen::Family;
use crate::matching::init::InitHeuristic;
use crate::runtime::Engine;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

pub struct Server {
    listener: TcpListener,
    executor: Executor,
    next_id: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port in tests).
    pub fn bind(addr: &str, engine: Option<Arc<Engine>>) -> std::io::Result<Self> {
        Self::bind_with(addr, engine, None, None)
    }

    /// [`Server::bind`] plus the durability knobs: with `data_dir` the
    /// store recovers from disk before the listener accepts its first
    /// connection, and all store traffic is persisted from then on;
    /// `max_graphs` caps the in-memory store (LRU, snapshot-on-evict).
    pub fn bind_with(
        addr: &str,
        engine: Option<Arc<Engine>>,
        data_dir: Option<std::path::PathBuf>,
        max_graphs: Option<usize>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let mut executor = Executor::new(engine, Arc::new(Metrics::new()));
        if let Some(dir) = data_dir {
            executor = executor
                .with_persistence(Arc::new(crate::persist::Persistence::open(dir)?));
        }
        if let Some(max) = max_graphs {
            executor = executor.with_max_graphs(max);
        }
        // recovery before the first accept: a client connecting right
        // after bind already sees the restored store (graphs_recovered in
        // STATS tells it how many came back)
        executor.recover()?;
        Ok(Self {
            listener,
            executor,
            next_id: Arc::new(AtomicU64::new(1)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// The server-side graph store (observability: the CLI prints how
    /// many graphs recovery restored before the first accept).
    pub fn store(&self) -> &Arc<super::store::GraphStore> {
        self.executor.store()
    }

    /// A handle that makes `serve` return after the in-flight accept.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop; returns when the stop handle is set (checked between
    /// connections — send any request to unblock accept).
    pub fn serve(&self) -> std::io::Result<()> {
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            let stream = conn?;
            let executor = self.executor.clone();
            let next_id = self.next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, executor, next_id);
            });
        }
        Ok(())
    }
}

fn handle_conn(
    stream: TcpStream,
    executor: Executor,
    next_id: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match handle_line(line.trim(), &executor, &next_id) {
            Command::Reply(s) => s,
            Command::Quit => return Ok(()),
        };
        stream.write_all(reply.as_bytes())?;
        stream.write_all(b"\n")?;
    }
}

enum Command {
    Reply(String),
    Quit,
}

fn handle_line(line: &str, executor: &Executor, next_id: &AtomicU64) -> Command {
    let mut parts = line.split_whitespace();
    let verb = parts.next();
    match verb {
        Some("QUIT") => return Command::Quit,
        Some("ALGOS") => {
            return Command::Reply(format!("ALGOS {}", registry::all_names().join(" ")))
        }
        Some("GRAPHS") => {
            let names = executor.store().names();
            return Command::Reply(if names.is_empty() {
                "GRAPHS".into()
            } else {
                format!("GRAPHS {}", names.join(" "))
            });
        }
        Some("STATS") => return Command::Reply(format!("STATS {}", executor.metrics.report())),
        Some("MATCH" | "LOAD" | "UPDATE" | "DROP" | "SAVE") => {}
        Some(other) => return Command::Reply(format!("ERR unknown command {other}")),
        None => return Command::Reply("ERR empty request".into()),
    }
    let verb = verb.expect("matched above");
    let kv: Vec<(&str, &str)> = parts.filter_map(|p| p.split_once('=')).collect();
    let parsed = match verb {
        "MATCH" => parse_match(&kv, next_id),
        "LOAD" => parse_load(&kv, next_id),
        "UPDATE" => parse_update(&kv, next_id),
        "DROP" => parse_drop(&kv, next_id),
        "SAVE" => parse_save(&kv, next_id),
        _ => unreachable!("verb filtered above"),
    };
    match parsed {
        Ok(job) => {
            let o = executor.execute(&job);
            match &o.error {
                Some(e) => Command::Reply(format!("ERR {e}")),
                None => Command::Reply(render_ok(&job, &o)),
            }
        }
        Err(e) => Command::Reply(format!("ERR {e}")),
    }
}

fn render_ok(job: &MatchJob, o: &MatchOutcome) -> String {
    use super::job::JobOp;
    match &job.op {
        JobOp::Load { name } => {
            format!("OK id={} name={} nr={} nc={} edges={}", o.job_id, name, o.nr, o.nc, o.n_edges)
        }
        JobOp::DropGraph { name } => format!("OK id={} name={} dropped=1", o.job_id, name),
        JobOp::Save { name } => format!(
            "OK id={} name={} saved=1 nr={} nc={} edges={}",
            o.job_id, name, o.nr, o.nc, o.n_edges
        ),
        JobOp::Match | JobOp::Update { .. } => {
            let mut s = format!(
                "OK id={} algo={} nr={} nc={} edges={} card={} certified={} \
                 t_load={:.6} t_match={:.6} frontier_peak={} endpoints={} \
                 devpar_cycles={}",
                o.job_id,
                o.algo,
                o.nr,
                o.nc,
                o.n_edges,
                o.cardinality,
                o.certified as u8,
                o.t_load,
                o.t_match,
                o.frontier_peak,
                o.endpoints_total,
                o.device_parallel_cycles
            );
            if let (JobOp::Update { name, .. }, Some(u)) = (&job.op, &o.update) {
                s.push_str(&format!(
                    " name={} inserted={} deleted={} cols_added={} rows_added={} \
                     rejected={} seeds={} dropped={} joined={} rebuilt={}",
                    name,
                    u.inserted,
                    u.deleted,
                    u.cols_added,
                    u.rows_added,
                    u.rejected,
                    u.seeds,
                    u.dropped,
                    u.joined,
                    u.rebuilt as u8
                ));
            }
            s
        }
    }
}

fn get<'a>(kv: &[(&'a str, &'a str)], k: &str) -> Option<&'a str> {
    kv.iter().find(|(key, _)| *key == k).map(|(_, v)| *v)
}

/// The `family=`/`n=`/`mtx=` graph-source fields shared by MATCH and LOAD.
fn parse_source(kv: &[(&str, &str)]) -> Result<GraphSource, String> {
    if let Some(path) = get(kv, "mtx") {
        return Ok(GraphSource::MtxFile(path.to_string()));
    }
    let family = get(kv, "family")
        .and_then(Family::from_name)
        .ok_or("missing/unknown family=")?;
    let n: usize = get(kv, "n")
        .ok_or("missing n=")?
        .parse()
        .map_err(|e| format!("bad n: {e}"))?;
    let seed: u64 =
        get(kv, "seed").unwrap_or("0").parse().map_err(|e| format!("bad seed: {e}"))?;
    let permute = get(kv, "permute").unwrap_or("0") == "1";
    Ok(GraphSource::Generate { family, n, seed, permute })
}

/// The `algo=`/`init=`/`timeout_ms=` execution fields shared by MATCH and
/// UPDATE. Parsed at the wire boundary: malformed values never reach the
/// executor.
fn apply_exec_fields(mut job: MatchJob, kv: &[(&str, &str)]) -> Result<MatchJob, String> {
    if let Some(a) = get(kv, "algo") {
        if a != "auto" {
            let spec: AlgoSpec = a.parse()?;
            job = job.with_spec(spec);
        }
    }
    if let Some(i) = get(kv, "init") {
        job.init = InitHeuristic::from_name(i).ok_or(format!("unknown init {i}"))?;
    }
    if let Some(t) = get(kv, "timeout_ms") {
        let ms: u64 = t.parse().map_err(|e| format!("bad timeout_ms: {e}"))?;
        job = job.with_timeout_ms(ms);
    }
    Ok(job)
}

fn parse_match(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    // `name=` targets a stored graph; otherwise the classic one-shot
    // sources apply
    let source = match get(kv, "name") {
        Some(name) => GraphSource::Stored(name.to_string()),
        None => parse_source(kv)?,
    };
    apply_exec_fields(MatchJob::new(id, source), kv)
}

fn parse_load(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("LOAD requires name=")?;
    let source = parse_source(kv)?;
    Ok(MatchJob::load_graph(id, name, source))
}

fn parse_update(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("UPDATE requires name=")?;
    let batch = DeltaBatch::from_wire(
        get(kv, "add"),
        get(kv, "del"),
        get(kv, "addcols"),
        get(kv, "addrows"),
    )?;
    if batch.is_empty() {
        return Err("empty UPDATE (set add=, del=, addcols=, or addrows=)".into());
    }
    apply_exec_fields(MatchJob::update_graph(id, name, batch), kv)
}

fn parse_drop(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("DROP requires name=")?;
    Ok(MatchJob::drop_graph(id, name))
}

fn parse_save(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("SAVE requires name=")?;
    Ok(MatchJob::save_graph(id, name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn start_server() -> (std::net::SocketAddr, Arc<AtomicBool>) {
        let server = Server::bind("127.0.0.1:0", None).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_handle();
        std::thread::spawn(move || server.serve());
        (addr, stop)
    }

    fn roundtrip(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim().to_string()
    }

    #[test]
    fn match_request_roundtrip() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=uniform n=200 seed=3 algo=hk");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("algo=hk"));
        assert!(reply.contains("certified=1"));
    }

    #[test]
    fn auto_routing_over_tcp() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=banded n=400 seed=1");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("card="));
    }

    #[test]
    fn algos_and_stats() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "ALGOS");
        assert!(reply.contains("hk") && reply.contains("gpu:APFB-GPUBFS-WR-CT"));
        let reply = roundtrip(addr, "STATS");
        assert!(reply.starts_with("STATS "));
    }

    #[test]
    fn errors_reported() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "MATCH family=nope n=10").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform").starts_with("ERR"));
        assert!(roundtrip(addr, "BOGUS").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=wat").starts_with("ERR"));
        // malformed specs are rejected at the wire boundary
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=gpu:NOPE-FC").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=p-hk@0").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 timeout_ms=abc").starts_with("ERR"));
    }

    #[test]
    fn ok_reply_exposes_compaction_counters() {
        let (addr, _stop) = start_server();
        // a compacted GPU run reports non-zero worklist counters
        let reply =
            roundtrip(addr, "MATCH family=road n=2000 seed=3 algo=gpu:APFB-GPUBFS-WR-CT-FC");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains(" frontier_peak="), "{reply}");
        assert!(reply.contains(" endpoints="), "{reply}");
        assert!(reply.contains(" devpar_cycles="), "{reply}");
        let field = |name: &str| -> u64 {
            reply
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix(name))
                .unwrap_or_else(|| panic!("{name} missing in {reply}"))
                .parse()
                .unwrap()
        };
        assert!(field("frontier_peak=") > 0, "{reply}");
        assert!(field("endpoints=") > 0, "{reply}");
        assert!(field("devpar_cycles=") > 0, "{reply}");
        // a CPU run reports zeros for all three
        let reply = roundtrip(addr, "MATCH family=uniform n=200 seed=1 algo=hk");
        assert!(reply.contains("frontier_peak=0"), "{reply}");
        assert!(reply.contains("endpoints=0"), "{reply}");
        assert!(reply.contains("devpar_cycles=0"), "{reply}");
    }

    #[test]
    fn timeout_ms_surfaces_as_distinct_timeout_error() {
        let (addr, _stop) = start_server();
        // deadline already expired when the matcher hits its first
        // checkpoint → the deadline-tripped job travels the whole
        // TCP path as a distinct "timeout" failure
        let reply = roundtrip(addr, "MATCH family=uniform n=20000 seed=1 algo=hk timeout_ms=0");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        // 1 ms against a graph whose generation alone exceeds it: the
        // deadline covers the whole job, so the first checkpoint trips
        let reply = roundtrip(addr, "MATCH family=uniform n=60000 seed=1 algo=hk timeout_ms=1");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        // a generous deadline does not interfere
        let reply =
            roundtrip(addr, "MATCH family=uniform n=300 seed=1 algo=hk timeout_ms=60000");
        assert!(reply.starts_with("OK "), "{reply}");
    }

    #[test]
    fn load_update_match_drop_verbs() {
        let (addr, _stop) = start_server();
        // LOAD
        let reply = roundtrip(addr, "LOAD name=g family=uniform n=300 seed=4");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("name=g"), "{reply}");
        assert!(reply.contains("edges="), "{reply}");
        let reply = roundtrip(addr, "GRAPHS");
        assert_eq!(reply, "GRAPHS g");
        // MATCH by name (cold)
        let reply = roundtrip(addr, "MATCH name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        // UPDATE: append a column wired to three rows; repair runs
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1;2");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains(" name=g"), "{reply}");
        assert!(reply.contains("cols_added=1"), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        assert!(reply.contains(" seeds="), "{reply}");
        // UPDATE with edge ops
        let reply = roundtrip(addr, "UPDATE name=g del=0:0 add=0:1");
        assert!(reply.starts_with("OK "), "{reply}");
        // STATS shows the update/store counters and the failure split
        let reply = roundtrip(addr, "STATS");
        assert!(reply.contains("updated=2"), "{reply}");
        assert!(reply.contains("loaded=1"), "{reply}");
        assert!(reply.contains("timeout=0"), "{reply}");
        assert!(reply.contains("cancelled=0"), "{reply}");
        // DROP
        let reply = roundtrip(addr, "DROP name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("dropped=1"), "{reply}");
        assert_eq!(roundtrip(addr, "GRAPHS"), "GRAPHS");
    }

    #[test]
    fn incremental_verb_errors() {
        let (addr, _stop) = start_server();
        // unknown names
        assert!(roundtrip(addr, "MATCH name=ghost").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=ghost add=0:0").starts_with("ERR"));
        assert!(roundtrip(addr, "DROP name=ghost").starts_with("ERR"));
        // missing/malformed fields rejected at the wire boundary
        assert!(roundtrip(addr, "LOAD family=uniform n=100").starts_with("ERR"));
        assert!(roundtrip(addr, "LOAD name=g family=nope n=100").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE add=0:0").starts_with("ERR"));
        let _ = roundtrip(addr, "LOAD name=g family=uniform n=100 seed=1");
        assert!(roundtrip(addr, "UPDATE name=g").starts_with("ERR"), "empty update");
        assert!(roundtrip(addr, "UPDATE name=g add=0-0").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=g addcols=x").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=g add=0:1 algo=wat").starts_with("ERR"));
    }

    #[test]
    fn addrows_and_save_verbs() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=200 seed=2").starts_with("OK "));
        // append two rows (one wired to cols 0 and 1, one isolated)
        let reply = roundtrip(addr, "UPDATE name=g addrows=0;1|");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("rows_added=2"), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        // malformed addrows rejected at the wire boundary
        assert!(roundtrip(addr, "UPDATE name=g addrows=x").starts_with("ERR"));
        // SAVE needs a data dir on this (volatile) server — typed refusal
        assert!(roundtrip(addr, "SAVE name=g").starts_with("ERR"), "volatile SAVE");
        assert!(roundtrip(addr, "SAVE").starts_with("ERR"), "SAVE requires name=");
    }

    #[test]
    fn durable_server_recovers_after_restart() {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_server_durable_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let start = |dir: &std::path::Path| {
            let server =
                Server::bind_with("127.0.0.1:0", None, Some(dir.to_path_buf()), None).unwrap();
            let addr = server.local_addr().unwrap();
            std::thread::spawn(move || server.serve());
            addr
        };
        let addr = start(&dir);
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=300 seed=9").starts_with("OK "));
        let first = roundtrip(addr, "MATCH name=g");
        assert!(first.contains("certified=1"), "{first}");
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1;2 del=0:0");
        assert!(reply.starts_with("OK "), "{reply}");
        let card = reply
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("card="))
            .unwrap()
            .to_string();
        let stats = roundtrip(addr, "STATS");
        assert!(stats.contains("wal_appends="), "{stats}");
        // "restart": a second server over the same data dir recovers the
        // graph and serves the identical cardinality, warm
        let addr2 = start(&dir);
        let stats = roundtrip(addr2, "STATS");
        assert!(stats.contains("recovered=1"), "{stats}");
        let reply = roundtrip(addr2, "MATCH name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(
            reply.contains(&format!(" card={card} ")),
            "want card={card}: {reply}"
        );
        assert!(reply.contains("certified=1"), "{reply}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_reports_timeout_split_over_the_wire() {
        // satellite regression: jobs_timed_out / jobs_cancelled travel the
        // STATS reply with real values, not just the counters
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=uniform n=20000 seed=1 algo=hk timeout_ms=0");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        let reply = roundtrip(addr, "STATS");
        assert!(reply.contains("timeout=1"), "{reply}");
        assert!(reply.contains("cancelled=0"), "{reply}");
        assert!(reply.contains("failed=1"), "{reply}");
    }

    #[test]
    fn multiple_requests_one_connection() {
        let (addr, _stop) = start_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"MATCH family=uniform n=100 seed=1 algo=bfs\nMATCH family=uniform n=100 seed=2 algo=dfs\nQUIT\n")
            .unwrap();
        let r = BufReader::new(s);
        let lines: Vec<String> = r.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 2);
        assert!(lines.iter().all(|l| l.starts_with("OK ")));
        // ids must differ
        assert_ne!(lines[0], lines[1]);
    }
}
