//! Line-protocol TCP front end for the matching service (no tokio offline;
//! std::net + one thread per connection, bounded by the accept loop).
//!
//! Protocol (one request per line, one reply per line):
//!
//! ```text
//! MATCH family=<name> n=<int> seed=<int> [permute=0|1] [algo=<name>]
//!       [init=<name>] [timeout_ms=<int>]
//! MATCH mtx=<path> [algo=<name>] [timeout_ms=<int>]
//! MATCH name=<graph> [algo=<name>] [timeout_ms=<int>]
//! LOAD  name=<graph> (family=… n=… [seed=…] [permute=0|1] | mtx=<path>)
//! UPDATE name=<graph> [add=r:c,r:c,…] [del=r:c,…] [addcols=r;r|r|…]
//!        [addrows=c;c|c|…] [algo=<name>] [timeout_ms=<int>]
//! DROP  name=<graph>
//! SAVE  name=<graph>          durable snapshot + WAL compaction now
//! ALGOS                       → ALGOS <name> <name> ...
//! GRAPHS                      → GRAPHS <name> <name> ...
//! STATS                       → STATS <metrics report>
//! STATS graph=<name>          → STATS graph=.. per-graph serving counters
//! TRACE [name=<graph>] [last=<n>]
//!                             → TRACE n=<k> header + k JSON trace lines
//! METRICS                     → Prometheus text exposition (multi-line)
//! LAG                         → LAG role=.. epoch=.. followers=.. shipped=..
//!                                   acked=.. lag=.. applied=.. connected=..
//! HEALTH                      → HEALTH role=.. epoch=.. version=.. git=..
//!                                   uptime_s=.. graphs=..
//! DUMP                        flight-recorder dump now → OK dump=<path> events=<n>
//! PROMOTE                     replica → writable primary (fences the old one)
//! REPLICA epoch=<e>           upgrade this connection to the event stream
//! QUIT
//! ```
//!
//! `TRACE` and `METRICS` are the two multi-line replies: `TRACE` sends a
//! `TRACE n=<k>` header followed by `k` one-object-per-line JSON traces
//! (newest first — see [`crate::trace::JobTrace::to_json_line`]),
//! `METRICS` sends the Prometheus 0.0.4 text exposition; both end with
//! one blank line so line-oriented clients can frame them. The server
//! records spans for every job by default ([`ServerCfg::trace_capacity`]
//! ring; set 0 to disarm), and [`ServerCfg::slow_ms`] adds the
//! slow-request log: any job at or over the threshold emits a warn-level
//! `slow_job` event (compact span breakdown, see [`crate::obs`]) and
//! counts under `jobs: slow=` in `STATS`.
//!
//! ## Observability ([`crate::obs`])
//!
//! Every server owns an [`Obs`] handle: lifecycle events (connections,
//! drain, eviction, recovery, promotion/fencing, follower traffic, WAL
//! compaction, slow jobs) go to stderr and — with a data dir — to
//! `<data-dir>/events.jsonl`, filtered by [`ServerCfg::log_level`].
//! The flight recorder rides along: a background flusher refreshes
//! `<data-dir>/flightrec/latest.jsonl` about once a second and a panic
//! hook writes a final dump, so a crashed or SIGKILL'd server leaves a
//! postmortem artifact. `DUMP` forces a dump on demand; `HEALTH` serves
//! the one-line liveness summary (role, epoch, build, uptime).
//!
//! `algo=` accepts any registry name (`AlgoSpec` wire format, including
//! `p-hk@<threads>`); malformed names are rejected before execution.
//! `timeout_ms=` sets a deadline over the whole job (load + init +
//! matching — and for `UPDATE`, apply + repair); a tripped job replies
//! `ERR timeout: ...` — a distinct failure, never a silently suboptimal
//! matching.
//!
//! The incremental verbs hold graphs server-side
//! ([`super::store::GraphStore`]): `LOAD` installs a graph under a name,
//! `UPDATE` ships a delta batch (`add`/`del` are comma-separated
//! `row:col` edges, `addcols`/`addrows` append columns/rows as
//! `|`-separated `;`-lists of neighbor ids — clauses apply in the
//! canonical order `addrows, addcols, add, del`, so an edge clause may
//! reference a vertex appended by the same request) and repairs the
//! maintained matching via seeded augmentation, and `MATCH name=…`
//! re-serves the cached maximum (warm start — one quiet phase). The
//! `STATS` report covers them (`updated=`, `graphs:
//! loaded=/dropped=/evicted=/recovered=`) next to the failure split
//! (`timeout=`, `cancelled=`), the durability counters (`persist:
//! wal_appends=/snapshots=`), and the replication counters (`repl:
//! shipped=/applied=/acks=/lag=`).
//!
//! When the server is bound with a data dir ([`Server::bind_with`]),
//! graphs survive restarts: `LOAD`s and `UPDATE`s are persisted (WAL +
//! snapshots, fsync'd before the OK reply), startup recovery replays the
//! log and repairs each matching, and `SAVE name=…` forces a snapshot +
//! log compaction on demand. See `crate::persist` for the guarantees.
//!
//! ## Replication ([`ServerCfg::replicate_from`])
//!
//! A server started with `replicate_from` is a **read replica**: it tails
//! the primary's event stream (see [`crate::persist::replicate`]),
//! replays every committed frame through the same incarnation-scoped
//! path crash recovery uses, serves `MATCH name=…` from the replicated
//! state, and rejects writes with `ERR read-only`. `PROMOTE` turns it
//! into the writable primary: the epoch bump + per-graph re-base fence
//! the dead primary, whose own `REPLICA` handshake (or any write) is
//! rejected if it ever comes back. `LAG` reports both sides of the
//! stream.
//!
//! ## Connection hardening and graceful shutdown
//!
//! Every connection has an idle read timeout ([`ServerCfg::idle_timeout`])
//! and a max request line length ([`ServerCfg::max_line_len`]) — a peer
//! that trickles bytes forever or ships an unbounded line is cut off, not
//! accumulated. When the stop handle is set, [`Server::serve`] stops
//! accepting, waits for in-flight *requests* to finish (bounded drain),
//! fsyncs every open WAL, joins the tailer, and returns — so a clean
//! SIGTERM never loses an acked write.
//!
//! Replies:
//! `OK id=<id> algo=<name> nr=.. nc=.. edges=.. card=.. certified=0|1
//!  phases=.. t_load=.. t_match=.. frontier_peak=.. endpoints=..
//!  devpar_cycles=.. shards=.. exchange_words=.. exchange_steps=..`
//! or `ERR <message>`. `phases=` exposes `RunStats::phases` so clients
//! (and the failover chaos test) can verify a warm start beat a cold
//! recompute. `frontier_peak=`/`endpoints=`/`devpar_cycles=` expose the
//! frontier-compaction counters (`RunStats::{frontier_peak,
//! endpoints_total, device_parallel_cycles}`); all three are 0 for CPU
//! algorithms and for FullScan GPU runs. `shards=`/`exchange_words=`/
//! `exchange_steps=` expose the sharded-execution counters
//! (`RunStats::{shards, exchange_words, exchange_steps}`); all three are
//! 0 unless the run used a `shard<K>:gpu:…` algorithm. `LOAD`/`DROP`/`SAVE` reply
//! `OK id=<id> name=<graph> nr=.. nc=.. edges=..` /
//! `OK id=<id> name=<graph> dropped=1` /
//! `OK id=<id> name=<graph> saved=1`; `UPDATE` appends
//! `inserted= deleted= cols_added= rows_added= rejected= seeds= dropped=
//! joined= rebuilt=` to the standard OK fields.

use super::exec::Executor;
use super::job::{GraphSource, MatchJob, MatchOutcome};
use super::metrics::Metrics;
use super::registry;
use super::spec::AlgoSpec;
use crate::dynamic::DeltaBatch;
use crate::graph::gen::Family;
use crate::matching::init::InitHeuristic;
use crate::obs::{self, flightrec, Level, Obs};
use crate::persist::replicate::{
    self, AckMode, Event, EventKind, LineIo, LineReader, TailerCfg,
};
use crate::persist::snapshot;
use crate::runtime::Engine;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Full server configuration ([`Server::bind_cfg`]). [`Server::bind`] and
/// [`Server::bind_with`] are the common-case shorthands.
pub struct ServerCfg {
    pub addr: String,
    pub engine: Option<Arc<Engine>>,
    /// durability: per-graph WAL + snapshots + startup recovery
    pub data_dir: Option<PathBuf>,
    /// LRU cap on in-memory stored graphs
    pub max_graphs: Option<usize>,
    /// start as a read replica tailing this primary (`host:port`)
    pub replicate_from: Option<String>,
    /// how writes are acknowledged (`local` = on the local fsync,
    /// `quorum` = only after a follower confirms the replicated event)
    pub ack_mode: AckMode,
    /// override the quorum ack wait (tests use a short one)
    pub ack_timeout: Option<Duration>,
    /// close a connection that produces no complete request line for this
    /// long
    pub idle_timeout: Duration,
    /// reject (and close) a connection that ships a longer request line
    pub max_line_len: usize,
    /// write snapshots as per-shard file sets of this size (1 = single
    /// file per snapshot); see `crate::persist::Persistence::set_snapshot_shards`
    pub snapshot_shards: usize,
    /// how many recent job traces the `TRACE` verb can serve (ring
    /// capacity); 0 disarms span recording entirely
    pub trace_capacity: usize,
    /// slow-request log threshold in ms (`--slow-ms`): jobs at or over it
    /// emit a warn-level `slow_job` event and count under `jobs_slow`
    pub slow_ms: Option<u64>,
    /// event-log sink threshold (`--log-level` / `BIMATCH_LOG`); see
    /// [`crate::obs::parse_filter`]. The flight recorder ignores it.
    pub log_level: u8,
}

impl ServerCfg {
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
            engine: None,
            data_dir: None,
            max_graphs: None,
            replicate_from: None,
            ack_mode: AckMode::Local,
            ack_timeout: None,
            idle_timeout: Duration::from_secs(120),
            max_line_len: 16 << 20,
            snapshot_shards: 1,
            trace_capacity: 256,
            slow_ms: None,
            log_level: obs::filter_from_env(),
        }
    }
}

/// Flight-recorder ring capacity: enough recent events for a useful
/// postmortem while keeping the per-event cost one short ring write.
const FLIGHTREC_CAPACITY: usize = 1024;

/// How often the background flusher refreshes `flightrec/latest.jsonl`.
const FLIGHTREC_FLUSH_EVERY: Duration = Duration::from_secs(1);

pub struct Server {
    listener: TcpListener,
    executor: Executor,
    next_id: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    /// in-flight request gauge — the graceful-shutdown drain waits on it
    active: Arc<AtomicU64>,
    idle_timeout: Duration,
    max_line_len: usize,
    tailer: Mutex<Option<std::thread::JoinHandle<()>>>,
    obs: Arc<Obs>,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Server {
    /// Bind to `addr` (use port 0 for an ephemeral port in tests).
    pub fn bind(addr: &str, engine: Option<Arc<Engine>>) -> std::io::Result<Self> {
        Self::bind_with(addr, engine, None, None)
    }

    /// [`Server::bind`] plus the durability knobs: with `data_dir` the
    /// store recovers from disk before the listener accepts its first
    /// connection, and all store traffic is persisted from then on;
    /// `max_graphs` caps the in-memory store (LRU, snapshot-on-evict).
    pub fn bind_with(
        addr: &str,
        engine: Option<Arc<Engine>>,
        data_dir: Option<PathBuf>,
        max_graphs: Option<usize>,
    ) -> std::io::Result<Self> {
        let mut cfg = ServerCfg::new(addr);
        cfg.engine = engine;
        cfg.data_dir = data_dir;
        cfg.max_graphs = max_graphs;
        Self::bind_cfg(cfg)
    }

    /// Bind from a full [`ServerCfg`] — the only path that can start a
    /// read replica (`replicate_from`) or switch the ack mode.
    pub fn bind_cfg(cfg: ServerCfg) -> std::io::Result<Self> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let obs = Obs::open(cfg.log_level, cfg.data_dir.clone(), FLIGHTREC_CAPACITY)?;
        flightrec::register_panic_dump(&obs);
        let mut executor =
            Executor::new(cfg.engine, Arc::new(Metrics::new())).with_obs(obs.clone());
        if let Some(dir) = &cfg.data_dir {
            let p = crate::persist::Persistence::open(dir)?;
            p.set_snapshot_shards(cfg.snapshot_shards);
            executor = executor.with_persistence(Arc::new(p));
        }
        if let Some(max) = cfg.max_graphs {
            executor = executor.with_max_graphs(max);
        }
        executor = executor.with_ack_mode(cfg.ack_mode);
        if let Some(t) = cfg.ack_timeout {
            executor = executor.with_ack_timeout(t);
        }
        if cfg.trace_capacity > 0 {
            executor = executor.with_trace_ring(crate::trace::TraceRing::new(cfg.trace_capacity));
        }
        if let Some(ms) = cfg.slow_ms {
            executor = executor.with_slow_threshold(Duration::from_millis(ms));
        }
        // recovery before the first accept: a client connecting right
        // after bind already sees the restored store (graphs_recovered in
        // STATS tells it how many came back)
        executor.recover()?;
        let stop = Arc::new(AtomicBool::new(false));
        let mut tailer = None;
        if let Some(primary) = cfg.replicate_from {
            // a replica is read-only from the first accept; the tailer
            // keeps resyncing (baseline snapshots + frames) until
            // shutdown, PROMOTE, or a fencing reply
            executor.set_read_only(true);
            let tcfg = TailerCfg {
                primary,
                role: executor.role().clone(),
                shutdown: stop.clone(),
                epoch_dir: cfg.data_dir.clone(),
                obs: Some(obs.clone()),
            };
            let exec = executor.clone();
            tailer = Some(
                std::thread::Builder::new()
                    .name("bimatch-replica-tailer".into())
                    .spawn(move || {
                        replicate::run_tailer(&tcfg, |ev| exec.apply_replicated_event(ev))
                    })
                    .expect("spawn tailer"),
            );
        }
        // the black box opens before the first accept: a crash during the
        // very first request still leaves `flightrec/latest.jsonl`
        obs.event(Level::Info, "server_started")
            .field("addr", &listener.local_addr().map_or_else(|_| cfg.addr.clone(), |a| a.to_string()))
            .field("role", executor.role_name())
            .field("log_level", obs::filter_name(cfg.log_level))
            .field_bool("durable", obs.data_dir().is_some())
            .emit();
        obs.flush_latest()?;
        let flusher = if obs.data_dir().is_some() {
            let o = obs.clone();
            let stop2 = stop.clone();
            Some(
                std::thread::Builder::new()
                    .name("bimatch-flightrec".into())
                    .spawn(move || {
                        // short poll so a stop is noticed promptly; the
                        // flush itself is skipped whenever the ring is
                        // clean since the last write
                        let mut since_flush = Duration::ZERO;
                        let poll = Duration::from_millis(100);
                        while !stop2.load(Ordering::Relaxed) {
                            std::thread::sleep(poll);
                            since_flush += poll;
                            if since_flush >= FLIGHTREC_FLUSH_EVERY {
                                since_flush = Duration::ZERO;
                                let _ = o.flush_latest();
                            }
                        }
                    })
                    .expect("spawn flight-recorder flusher"),
            )
        } else {
            None
        };
        Ok(Self {
            listener,
            executor,
            next_id: Arc::new(AtomicU64::new(1)),
            stop,
            active: Arc::new(AtomicU64::new(0)),
            idle_timeout: cfg.idle_timeout,
            max_line_len: cfg.max_line_len,
            tailer: Mutex::new(tailer),
            obs,
            flusher: Mutex::new(flusher),
        })
    }

    pub fn local_addr(&self) -> std::io::Result<std::net::SocketAddr> {
        self.listener.local_addr()
    }

    /// The server-side graph store (observability: the CLI prints how
    /// many graphs recovery restored before the first accept).
    pub fn store(&self) -> &Arc<super::store::GraphStore> {
        self.executor.store()
    }

    /// The executor (tests reach the role/hub/metrics through it).
    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// A handle that makes `serve` return: stop accepting, drain
    /// in-flight requests, fsync the WALs, join the tailer.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Accept loop; returns when the stop handle is set. Shutdown is
    /// graceful: requests already being executed finish and get their
    /// replies (bounded by a 10 s drain), every open WAL is fsync'd, and
    /// the replica tailer (if any) is joined — an acked write can never
    /// be lost to a clean stop.
    pub fn serve(&self) -> std::io::Result<()> {
        self.listener.set_nonblocking(true)?;
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let executor = self.executor.clone();
                    let next_id = self.next_id.clone();
                    let stop = self.stop.clone();
                    let active = self.active.clone();
                    let idle_timeout = self.idle_timeout;
                    let max_line_len = self.max_line_len;
                    std::thread::spawn(move || {
                        let _ = handle_conn(
                            stream,
                            executor,
                            next_id,
                            stop,
                            active,
                            idle_timeout,
                            max_line_len,
                        );
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(15));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        // drain: connection threads notice `stop` within one read-poll and
        // exit after finishing (and replying to) their current request
        self.obs
            .event(Level::Info, "drain")
            .field_u64("in_flight", self.active.load(Ordering::Relaxed))
            .emit();
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.active.load(Ordering::Relaxed) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        // belt-and-braces fsync of every WAL (each acked append already
        // synced; this closes the window for anything else)
        if let Some(p) = self.executor.persistence() {
            p.sync_all()?;
        }
        if let Some(h) = self.tailer.lock().unwrap().take() {
            let _ = h.join();
        }
        self.obs
            .event(Level::Info, "server_stopped")
            .field_u64("drained_in_flight", self.active.load(Ordering::Relaxed))
            .emit();
        if let Some(h) = self.flusher.lock().unwrap().take() {
            let _ = h.join();
        }
        self.obs.flush_latest()?;
        Ok(())
    }
}

/// Decrements the in-flight gauge on every exit path of a request.
struct ActiveGuard(Arc<AtomicU64>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

fn handle_conn(
    stream: TcpStream,
    executor: Executor,
    next_id: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicU64>,
    idle_timeout: Duration,
    max_line_len: usize,
) -> std::io::Result<()> {
    // short read poll so both the idle timeout and a server stop are
    // noticed promptly; LineReader accumulates partial lines across polls
    let poll = Duration::from_millis(200).min(idle_timeout.max(Duration::from_millis(1)));
    stream.set_read_timeout(Some(poll))?;
    let peer = stream.peer_addr().map_or_else(|_| "?".into(), |a| a.to_string());
    let conn_event = |level: Level, kind: &'static str| {
        if let Some(o) = executor.obs() {
            o.event(level, kind).field("peer", &peer).emit();
        }
    };
    conn_event(Level::Debug, "conn_accept");
    // names the close cause in `conn_close` (eof/quit/idle/stop/
    // line_too_long/io_error); set before every exit path
    let close = |reason: &str, requests: u64| {
        if let Some(o) = executor.obs() {
            o.event(Level::Debug, "conn_close")
                .field("peer", &peer)
                .field("reason", reason)
                .field_u64("requests", requests)
                .emit();
        }
    };
    let mut lines = LineReader::new(BufReader::new(stream.try_clone()?));
    let mut stream = stream;
    let mut idle = Duration::ZERO;
    let mut requests: u64 = 0;
    let result = loop {
        match lines.next_line(max_line_len) {
            Err(e) => {
                close("io_error", requests);
                return Err(e);
            }
            Ok(LineIo::Eof) => break "eof", // client closed
            Ok(LineIo::TooLong) => {
                let _ = stream.write_all(
                    format!("ERR line too long (max {max_line_len} bytes)\n").as_bytes(),
                );
                break "line_too_long";
            }
            Ok(LineIo::Idle) => {
                idle += poll;
                if stop.load(Ordering::Relaxed) {
                    break "stop";
                }
                if idle >= idle_timeout {
                    break "idle";
                }
            }
            Ok(LineIo::Line(line)) => {
                idle = Duration::ZERO;
                let line = line.trim();
                if line.split_whitespace().next() == Some("REPLICA") {
                    // the connection upgrades to a one-way event stream
                    return serve_replica(stream, lines, line, &executor, &stop);
                }
                requests += 1;
                active.fetch_add(1, Ordering::Relaxed);
                let _guard = ActiveGuard(active.clone());
                let reply = match handle_line(line, &executor, &next_id) {
                    Command::Reply(s) => s,
                    Command::Quit => break "quit",
                };
                let wrote = stream
                    .write_all(reply.as_bytes())
                    .and_then(|()| stream.write_all(b"\n"));
                if let Err(e) = wrote {
                    close("io_error", requests);
                    return Err(e);
                }
            }
        }
    };
    close(result, requests);
    Ok(())
}

/// The primary half of the replication stream: handshake (epoch fencing
/// both ways), baseline snapshots, then fan-out + acks until the follower
/// hangs up or the server stops.
fn serve_replica(
    mut stream: TcpStream,
    mut lines: LineReader<BufReader<TcpStream>>,
    handshake: &str,
    executor: &Executor,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    let remote_epoch = handshake
        .split_whitespace()
        .find_map(|t| t.strip_prefix("epoch="))
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0);
    let role = executor.role();
    role.primary_epoch_seen.fetch_max(remote_epoch, Ordering::Relaxed);
    let local_epoch = role.epoch();
    let peer = stream.peer_addr().map_or_else(|_| "?".into(), |a| a.to_string());
    if remote_epoch > local_epoch {
        // the peer outranks us: a promotion happened behind our back.
        // Refuse the stream AND fence ourselves — an ex-primary that
        // keeps accepting writes would split-brain.
        role.fenced.store(true, Ordering::Relaxed);
        if let Some(o) = executor.obs() {
            o.event(Level::Warn, "self_fenced")
                .field("peer", &peer)
                .field_u64("peer_epoch", remote_epoch)
                .field_u64("local_epoch", local_epoch)
                .emit();
        }
        stream.write_all(
            format!(
                "ERR fenced: peer epoch {remote_epoch} > local {local_epoch} \
                 (this node was failed over; writes are now rejected)\n"
            )
            .as_bytes(),
        )?;
        return Ok(());
    }
    // subscribe BEFORE reading the baseline: every event published while
    // the snapshots are being captured is already queued for this
    // follower, and replaying a queued frame the baseline already covers
    // is a no-op (≤-version skip) — no gap, no double-apply
    let hub = executor.hub().clone();
    let (floor_seq, sub_id, rx) = hub.subscribe();
    if let Some(o) = executor.obs() {
        o.event(Level::Info, "follower_connect")
            .field("peer", &peer)
            .field_u64("epoch", remote_epoch)
            .field_u64("floor_seq", floor_seq)
            .emit();
    }
    stream.write_all(format!("OK epoch={local_epoch}\n").as_bytes())?;
    let result = (|| -> std::io::Result<()> {
        for name in executor.store().names() {
            let Some(view) = executor.store().graph_for_match(&name) else { continue };
            let data = snapshot::encode_snapshot(
                view.version,
                &view.graph,
                view.cached.as_ref().map(|c| &c.matching),
            );
            let ev = Event { seq: floor_seq, kind: EventKind::Snap, name, data };
            stream.write_all(replicate::render_event(&ev).as_bytes())?;
            stream.write_all(b"\n")?;
        }
        // the stream half: forward published events, absorb ACK lines
        stream.set_read_timeout(Some(Duration::from_millis(25)))?;
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            while let Ok(line) = rx.try_recv() {
                stream.write_all(line.as_bytes())?;
            }
            match lines.next_line(0)? {
                LineIo::Idle => {}
                LineIo::Eof | LineIo::TooLong => return Ok(()),
                LineIo::Line(l) => {
                    if let Some(seq) = replicate::parse_ack(&l) {
                        hub.ack(seq);
                        executor.metrics.repl_acks.fetch_add(1, Ordering::Relaxed);
                        executor.metrics.repl_lag.store(hub.lag(), Ordering::Relaxed);
                    }
                }
            }
        }
    })();
    hub.unsubscribe(sub_id);
    if let Some(o) = executor.obs() {
        o.event(Level::Info, "follower_disconnect")
            .field("peer", &peer)
            .field_u64("lag", hub.lag())
            .emit();
    }
    result
}

enum Command {
    Reply(String),
    Quit,
}

fn handle_line(line: &str, executor: &Executor, next_id: &AtomicU64) -> Command {
    let mut parts = line.split_whitespace();
    let verb = parts.next();
    match verb {
        Some("QUIT") => return Command::Quit,
        Some("ALGOS") => {
            return Command::Reply(format!("ALGOS {}", registry::all_names().join(" ")))
        }
        Some("GRAPHS") => {
            let names = executor.store().names();
            return Command::Reply(if names.is_empty() {
                "GRAPHS".into()
            } else {
                format!("GRAPHS {}", names.join(" "))
            });
        }
        Some("STATS") => {
            let kv: Vec<(&str, &str)> = parts.filter_map(|p| p.split_once('=')).collect();
            return Command::Reply(match get(&kv, "graph") {
                None => format!("STATS {}", executor.metrics.report()),
                Some(name) => render_graph_stats(executor, name),
            });
        }
        Some("TRACE") => {
            let kv: Vec<(&str, &str)> = parts.filter_map(|p| p.split_once('=')).collect();
            return Command::Reply(render_traces(executor, &kv));
        }
        Some("METRICS") => return Command::Reply(executor.prometheus()),
        Some("LAG") => return Command::Reply(render_lag(executor)),
        Some("HEALTH") => return Command::Reply(render_health(executor)),
        Some("DUMP") => {
            return Command::Reply(match executor.obs() {
                None => "ERR no event log attached".into(),
                Some(o) => match o.dump("request") {
                    Ok((path, events)) => {
                        format!("OK dump={} events={events}", path.display())
                    }
                    Err(e) => format!("ERR dump failed: {e}"),
                },
            })
        }
        Some("PROMOTE") => {
            return Command::Reply(match executor.promote() {
                Ok((epoch, graphs)) => {
                    format!("OK promoted=1 epoch={epoch} graphs={graphs}")
                }
                Err(e) => format!("ERR {e}"),
            })
        }
        Some("MATCH" | "LOAD" | "UPDATE" | "DROP" | "SAVE") => {}
        Some(other) => return Command::Reply(format!("ERR unknown command {other}")),
        None => return Command::Reply("ERR empty request".into()),
    }
    let verb = verb.expect("matched above");
    let kv: Vec<(&str, &str)> = parts.filter_map(|p| p.split_once('=')).collect();
    let parsed = match verb {
        "MATCH" => parse_match(&kv, next_id),
        "LOAD" => parse_load(&kv, next_id),
        "UPDATE" => parse_update(&kv, next_id),
        "DROP" => parse_drop(&kv, next_id),
        "SAVE" => parse_save(&kv, next_id),
        _ => unreachable!("verb filtered above"),
    };
    match parsed {
        Ok(job) => {
            let o = executor.execute(&job);
            match &o.error {
                Some(e) => Command::Reply(format!("ERR {e}")),
                None => Command::Reply(render_ok(&job, &o)),
            }
        }
        Err(e) => Command::Reply(format!("ERR {e}")),
    }
}

/// The `STATS graph=<name>` reply: the per-graph serving breakdown
/// ([`super::store::GraphStats`]) in one line.
fn render_graph_stats(executor: &Executor, name: &str) -> String {
    match executor.store().graph_stats(name) {
        None => format!("ERR no stored graph named {name:?}"),
        Some((s, version, cardinality)) => format!(
            "STATS graph={name} version={version} cached_cardinality={} matches={} \
             recomputes={} updates={} repairs={} edges_inserted={} edges_deleted={} \
             cols_added={} rows_added={} wal_appends={} snapshots={}",
            cardinality.map_or_else(|| "-".to_string(), |c| c.to_string()),
            s.matches,
            s.recomputes,
            s.updates,
            s.repairs,
            s.edges_inserted,
            s.edges_deleted,
            s.cols_added,
            s.rows_added,
            s.wal_appends,
            s.snapshots,
        ),
    }
}

/// The `TRACE` reply: a `TRACE n=<k>` header, then `k` JSON trace lines
/// (newest first), optionally filtered by `name=` and bounded by `last=`
/// (default 10).
fn render_traces(executor: &Executor, kv: &[(&str, &str)]) -> String {
    let Some(ring) = executor.trace_ring() else {
        return "ERR tracing disabled (trace_capacity=0)".into();
    };
    let last = match get(kv, "last") {
        None => 10,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n.max(1),
            Err(e) => return format!("ERR bad last: {e}"),
        },
    };
    let traces = ring.recent(get(kv, "name"), last);
    // the reply ends with '\n'; the connection loop's own '\n' then
    // yields the blank line that frames this multi-line reply
    let mut s = format!("TRACE n={}\n", traces.len());
    for t in &traces {
        s.push_str(&t.to_json_line());
        s.push('\n');
    }
    s
}

/// The `HEALTH` reply: liveness + identity in one line — what a probe
/// or a fleet dashboard wants without parsing the Prometheus text.
fn render_health(executor: &Executor) -> String {
    format!(
        "HEALTH role={} epoch={} version={} git={} uptime_s={} graphs={}",
        executor.role_name(),
        executor.role().epoch(),
        env!("CARGO_PKG_VERSION"),
        env!("BIMATCH_GIT_HASH"),
        executor.metrics.uptime_seconds(),
        executor.store().names().len(),
    )
}

/// The `LAG` reply: both sides of the replication stream in one line.
fn render_lag(executor: &Executor) -> String {
    let role = executor.role();
    let hub = executor.hub();
    format!(
        "LAG role={} epoch={} followers={} shipped={} acked={} lag={} applied={} connected={}",
        executor.role_name(),
        role.epoch(),
        hub.subscriber_count(),
        hub.last_seq(),
        hub.max_acked(),
        hub.lag(),
        executor.metrics.repl_frames_applied.load(Ordering::Relaxed),
        role.tailer_connected.load(Ordering::Relaxed) as u8,
    )
}

fn render_ok(job: &MatchJob, o: &MatchOutcome) -> String {
    use super::job::JobOp;
    match &job.op {
        JobOp::Load { name } => {
            format!("OK id={} name={} nr={} nc={} edges={}", o.job_id, name, o.nr, o.nc, o.n_edges)
        }
        JobOp::DropGraph { name } => format!("OK id={} name={} dropped=1", o.job_id, name),
        JobOp::Save { name } => format!(
            "OK id={} name={} saved=1 nr={} nc={} edges={}",
            o.job_id, name, o.nr, o.nc, o.n_edges
        ),
        JobOp::Match | JobOp::Update { .. } => {
            let mut s = format!(
                "OK id={} algo={} nr={} nc={} edges={} card={} certified={} \
                 phases={} t_load={:.6} t_match={:.6} frontier_peak={} endpoints={} \
                 devpar_cycles={} shards={} exchange_words={} exchange_steps={}",
                o.job_id,
                o.algo,
                o.nr,
                o.nc,
                o.n_edges,
                o.cardinality,
                o.certified as u8,
                o.phases,
                o.t_load,
                o.t_match,
                o.frontier_peak,
                o.endpoints_total,
                o.device_parallel_cycles,
                o.shards,
                o.exchange_words,
                o.exchange_steps
            );
            if let (JobOp::Update { name, .. }, Some(u)) = (&job.op, &o.update) {
                s.push_str(&format!(
                    " name={} inserted={} deleted={} cols_added={} rows_added={} \
                     rejected={} seeds={} dropped={} joined={} rebuilt={}",
                    name,
                    u.inserted,
                    u.deleted,
                    u.cols_added,
                    u.rows_added,
                    u.rejected,
                    u.seeds,
                    u.dropped,
                    u.joined,
                    u.rebuilt as u8
                ));
            }
            s
        }
    }
}

fn get<'a>(kv: &[(&'a str, &'a str)], k: &str) -> Option<&'a str> {
    kv.iter().find(|(key, _)| *key == k).map(|(_, v)| *v)
}

/// The `family=`/`n=`/`mtx=` graph-source fields shared by MATCH and LOAD.
fn parse_source(kv: &[(&str, &str)]) -> Result<GraphSource, String> {
    if let Some(path) = get(kv, "mtx") {
        return Ok(GraphSource::MtxFile(path.to_string()));
    }
    let family = get(kv, "family")
        .and_then(Family::from_name)
        .ok_or("missing/unknown family=")?;
    let n: usize = get(kv, "n")
        .ok_or("missing n=")?
        .parse()
        .map_err(|e| format!("bad n: {e}"))?;
    let seed: u64 =
        get(kv, "seed").unwrap_or("0").parse().map_err(|e| format!("bad seed: {e}"))?;
    let permute = get(kv, "permute").unwrap_or("0") == "1";
    Ok(GraphSource::Generate { family, n, seed, permute })
}

/// The `algo=`/`init=`/`timeout_ms=` execution fields shared by MATCH and
/// UPDATE. Parsed at the wire boundary: malformed values never reach the
/// executor.
fn apply_exec_fields(mut job: MatchJob, kv: &[(&str, &str)]) -> Result<MatchJob, String> {
    if let Some(a) = get(kv, "algo") {
        if a != "auto" {
            let spec: AlgoSpec = a.parse()?;
            job = job.with_spec(spec);
        }
    }
    if let Some(i) = get(kv, "init") {
        job.init = InitHeuristic::from_name(i).ok_or(format!("unknown init {i}"))?;
    }
    if let Some(t) = get(kv, "timeout_ms") {
        let ms: u64 = t.parse().map_err(|e| format!("bad timeout_ms: {e}"))?;
        job = job.with_timeout_ms(ms);
    }
    Ok(job)
}

fn parse_match(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    // `name=` targets a stored graph; otherwise the classic one-shot
    // sources apply
    let source = match get(kv, "name") {
        Some(name) => GraphSource::Stored(name.to_string()),
        None => parse_source(kv)?,
    };
    apply_exec_fields(MatchJob::new(id, source), kv)
}

fn parse_load(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("LOAD requires name=")?;
    let source = parse_source(kv)?;
    Ok(MatchJob::load_graph(id, name, source))
}

fn parse_update(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("UPDATE requires name=")?;
    let batch = DeltaBatch::from_wire(
        get(kv, "add"),
        get(kv, "del"),
        get(kv, "addcols"),
        get(kv, "addrows"),
    )?;
    if batch.is_empty() {
        return Err("empty UPDATE (set add=, del=, addcols=, or addrows=)".into());
    }
    apply_exec_fields(MatchJob::update_graph(id, name, batch), kv)
}

fn parse_drop(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("DROP requires name=")?;
    Ok(MatchJob::drop_graph(id, name))
}

fn parse_save(kv: &[(&str, &str)], next_id: &AtomicU64) -> Result<MatchJob, String> {
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let name = get(kv, "name").ok_or("SAVE requires name=")?;
    Ok(MatchJob::save_graph(id, name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};

    fn start_server() -> (std::net::SocketAddr, Arc<AtomicBool>) {
        let server = Server::bind("127.0.0.1:0", None).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_handle();
        std::thread::spawn(move || server.serve());
        (addr, stop)
    }

    fn roundtrip(addr: std::net::SocketAddr, req: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.trim().to_string()
    }

    #[test]
    fn match_request_roundtrip() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=uniform n=200 seed=3 algo=hk");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("algo=hk"));
        assert!(reply.contains("certified=1"));
    }

    #[test]
    fn auto_routing_over_tcp() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=banded n=400 seed=1");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("card="));
    }

    #[test]
    fn algos_and_stats() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "ALGOS");
        assert!(reply.contains("hk") && reply.contains("gpu:APFB-GPUBFS-WR-CT"));
        let reply = roundtrip(addr, "STATS");
        assert!(reply.starts_with("STATS "));
    }

    #[test]
    fn errors_reported() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "MATCH family=nope n=10").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform").starts_with("ERR"));
        assert!(roundtrip(addr, "BOGUS").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=wat").starts_with("ERR"));
        // malformed specs are rejected at the wire boundary
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=gpu:NOPE-FC").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 algo=p-hk@0").starts_with("ERR"));
        assert!(roundtrip(addr, "MATCH family=uniform n=50 timeout_ms=abc").starts_with("ERR"));
    }

    #[test]
    fn ok_reply_exposes_compaction_counters() {
        let (addr, _stop) = start_server();
        // a compacted GPU run reports non-zero worklist counters
        let reply =
            roundtrip(addr, "MATCH family=road n=2000 seed=3 algo=gpu:APFB-GPUBFS-WR-CT-FC");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains(" frontier_peak="), "{reply}");
        assert!(reply.contains(" endpoints="), "{reply}");
        assert!(reply.contains(" devpar_cycles="), "{reply}");
        let field = |name: &str| -> u64 {
            reply
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix(name))
                .unwrap_or_else(|| panic!("{name} missing in {reply}"))
                .parse()
                .unwrap()
        };
        assert!(field("frontier_peak=") > 0, "{reply}");
        assert!(field("endpoints=") > 0, "{reply}");
        assert!(field("devpar_cycles=") > 0, "{reply}");
        // and every MATCH/UPDATE OK line carries phases= (the failover
        // test compares warm vs cold through it)
        assert!(reply.contains(" phases="), "{reply}");
        assert!(field("phases=") > 0, "{reply}");
        // an unsharded run reports shards=0 and no exchange traffic
        assert!(reply.contains(" shards=0"), "{reply}");
        assert!(reply.contains(" exchange_words=0"), "{reply}");
        assert!(reply.contains(" exchange_steps=0"), "{reply}");
        // a CPU run reports zeros for all three
        let reply = roundtrip(addr, "MATCH family=uniform n=200 seed=1 algo=hk");
        assert!(reply.contains("frontier_peak=0"), "{reply}");
        assert!(reply.contains("endpoints=0"), "{reply}");
        assert!(reply.contains("devpar_cycles=0"), "{reply}");
        assert!(reply.contains("shards=0"), "{reply}");
    }

    #[test]
    fn ok_reply_exposes_sharded_counters() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(
            addr,
            "MATCH family=uniform n=1200 seed=5 algo=shard4:gpu:APFB-GPUBFS-WR-CT-FC",
        );
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("algo=shard4:gpu:APFB-GPUBFS-WR-CT-FC"), "{reply}");
        let field = |name: &str| -> u64 {
            reply
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix(name))
                .unwrap_or_else(|| panic!("{name} missing in {reply}"))
                .parse()
                .unwrap()
        };
        assert_eq!(field("shards="), 4, "{reply}");
        assert!(field("exchange_steps=") > 0, "{reply}");
        let words = field("exchange_words=");
        assert!(words > 0, "{reply}");
        assert_eq!(words % crate::gpu::device::EXCHANGE_WORDS_PER_ITEM, 0, "{reply}");
    }

    #[test]
    fn timeout_ms_surfaces_as_distinct_timeout_error() {
        let (addr, _stop) = start_server();
        // deadline already expired when the matcher hits its first
        // checkpoint → the deadline-tripped job travels the whole
        // TCP path as a distinct "timeout" failure
        let reply = roundtrip(addr, "MATCH family=uniform n=20000 seed=1 algo=hk timeout_ms=0");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        // 1 ms against a graph whose generation alone exceeds it: the
        // deadline covers the whole job, so the first checkpoint trips
        let reply = roundtrip(addr, "MATCH family=uniform n=60000 seed=1 algo=hk timeout_ms=1");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        // a generous deadline does not interfere
        let reply =
            roundtrip(addr, "MATCH family=uniform n=300 seed=1 algo=hk timeout_ms=60000");
        assert!(reply.starts_with("OK "), "{reply}");
    }

    #[test]
    fn load_update_match_drop_verbs() {
        let (addr, _stop) = start_server();
        // LOAD
        let reply = roundtrip(addr, "LOAD name=g family=uniform n=300 seed=4");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("name=g"), "{reply}");
        assert!(reply.contains("edges="), "{reply}");
        let reply = roundtrip(addr, "GRAPHS");
        assert_eq!(reply, "GRAPHS g");
        // MATCH by name (cold)
        let reply = roundtrip(addr, "MATCH name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        // UPDATE: append a column wired to three rows; repair runs
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1;2");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains(" name=g"), "{reply}");
        assert!(reply.contains("cols_added=1"), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        assert!(reply.contains(" seeds="), "{reply}");
        // UPDATE with edge ops
        let reply = roundtrip(addr, "UPDATE name=g del=0:0 add=0:1");
        assert!(reply.starts_with("OK "), "{reply}");
        // STATS shows the update/store counters and the failure split
        let reply = roundtrip(addr, "STATS");
        assert!(reply.contains("updated=2"), "{reply}");
        assert!(reply.contains("loaded=1"), "{reply}");
        assert!(reply.contains("timeout=0"), "{reply}");
        assert!(reply.contains("cancelled=0"), "{reply}");
        // DROP
        let reply = roundtrip(addr, "DROP name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("dropped=1"), "{reply}");
        assert_eq!(roundtrip(addr, "GRAPHS"), "GRAPHS");
    }

    #[test]
    fn incremental_verb_errors() {
        let (addr, _stop) = start_server();
        // unknown names
        assert!(roundtrip(addr, "MATCH name=ghost").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=ghost add=0:0").starts_with("ERR"));
        assert!(roundtrip(addr, "DROP name=ghost").starts_with("ERR"));
        // missing/malformed fields rejected at the wire boundary
        assert!(roundtrip(addr, "LOAD family=uniform n=100").starts_with("ERR"));
        assert!(roundtrip(addr, "LOAD name=g family=nope n=100").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE add=0:0").starts_with("ERR"));
        let _ = roundtrip(addr, "LOAD name=g family=uniform n=100 seed=1");
        assert!(roundtrip(addr, "UPDATE name=g").starts_with("ERR"), "empty update");
        assert!(roundtrip(addr, "UPDATE name=g add=0-0").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=g addcols=x").starts_with("ERR"));
        assert!(roundtrip(addr, "UPDATE name=g add=0:1 algo=wat").starts_with("ERR"));
    }

    #[test]
    fn addrows_and_save_verbs() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=200 seed=2").starts_with("OK "));
        // append two rows (one wired to cols 0 and 1, one isolated)
        let reply = roundtrip(addr, "UPDATE name=g addrows=0;1|");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains("rows_added=2"), "{reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        // malformed addrows rejected at the wire boundary
        assert!(roundtrip(addr, "UPDATE name=g addrows=x").starts_with("ERR"));
        // SAVE needs a data dir on this (volatile) server — typed refusal
        assert!(roundtrip(addr, "SAVE name=g").starts_with("ERR"), "volatile SAVE");
        assert!(roundtrip(addr, "SAVE").starts_with("ERR"), "SAVE requires name=");
    }

    #[test]
    fn durable_server_recovers_after_restart() {
        let dir = std::env::temp_dir().join(format!(
            "bimatch_server_durable_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let start = |dir: &std::path::Path| {
            let server =
                Server::bind_with("127.0.0.1:0", None, Some(dir.to_path_buf()), None).unwrap();
            let addr = server.local_addr().unwrap();
            std::thread::spawn(move || server.serve());
            addr
        };
        let addr = start(&dir);
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=300 seed=9").starts_with("OK "));
        let first = roundtrip(addr, "MATCH name=g");
        assert!(first.contains("certified=1"), "{first}");
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1;2 del=0:0");
        assert!(reply.starts_with("OK "), "{reply}");
        let card = reply
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("card="))
            .unwrap()
            .to_string();
        let stats = roundtrip(addr, "STATS");
        assert!(stats.contains("wal_appends="), "{stats}");
        // "restart": a second server over the same data dir recovers the
        // graph and serves the identical cardinality, warm
        let addr2 = start(&dir);
        let stats = roundtrip(addr2, "STATS");
        assert!(stats.contains("recovered=1"), "{stats}");
        let reply = roundtrip(addr2, "MATCH name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(
            reply.contains(&format!(" card={card} ")),
            "want card={card}: {reply}"
        );
        assert!(reply.contains("certified=1"), "{reply}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_reports_timeout_split_over_the_wire() {
        // satellite regression: jobs_timed_out / jobs_cancelled travel the
        // STATS reply with real values, not just the counters
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "MATCH family=uniform n=20000 seed=1 algo=hk timeout_ms=0");
        assert!(reply.starts_with("ERR timeout:"), "{reply}");
        let reply = roundtrip(addr, "STATS");
        assert!(reply.contains("timeout=1"), "{reply}");
        assert!(reply.contains("cancelled=0"), "{reply}");
        assert!(reply.contains("failed=1"), "{reply}");
    }

    #[test]
    fn multiple_requests_one_connection() {
        let (addr, _stop) = start_server();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"MATCH family=uniform n=100 seed=1 algo=bfs\nMATCH family=uniform n=100 seed=2 algo=dfs\nQUIT\n")
            .unwrap();
        let r = BufReader::new(s);
        let lines: Vec<String> = r.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 2);
        assert!(lines.iter().all(|l| l.starts_with("OK ")));
        // ids must differ
        assert_ne!(lines[0], lines[1]);
    }

    #[test]
    fn oversized_line_is_rejected_and_connection_closed() {
        let mut cfg = ServerCfg::new("127.0.0.1:0");
        cfg.max_line_len = 64;
        let server = Server::bind_cfg(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        std::thread::spawn(move || server.serve());
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&vec![b'a'; 256]).unwrap();
        s.write_all(b"\n").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR line too long"), "{line}");
        // the server hung up after the refusal
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "connection must be closed");
    }

    #[test]
    fn idle_connection_is_closed_but_active_one_survives() {
        let mut cfg = ServerCfg::new("127.0.0.1:0");
        cfg.idle_timeout = Duration::from_millis(300);
        let server = Server::bind_cfg(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        std::thread::spawn(move || server.serve());
        // an idle peer is cut off once the timeout elapses
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        let n = r.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "idle connection must be closed, got {line:?}");
        // a peer that keeps issuing requests within the window stays up
        let mut s = TcpStream::connect(addr).unwrap();
        for _ in 0..3 {
            std::thread::sleep(Duration::from_millis(150));
            s.write_all(b"GRAPHS\n").unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(line.starts_with("GRAPHS"), "{line}");
        }
    }

    #[test]
    fn lag_and_promote_verbs_on_a_plain_primary() {
        let (addr, _stop) = start_server();
        let reply = roundtrip(addr, "LAG");
        assert!(reply.starts_with("LAG role=primary "), "{reply}");
        assert!(reply.contains("followers=0"), "{reply}");
        assert!(reply.contains("lag=0"), "{reply}");
        // promoting a node that is already writable is a typed error
        let reply = roundtrip(addr, "PROMOTE");
        assert!(reply.starts_with("ERR"), "{reply}");
        assert!(reply.contains("already writable"), "{reply}");
    }

    #[test]
    fn replica_handshake_with_higher_epoch_fences_the_node() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=100 seed=1").starts_with("OK "));
        // a peer claiming a higher epoch means we were failed over: the
        // handshake is refused and this node stops accepting writes
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"REPLICA epoch=7\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR fenced:"), "{line}");
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1");
        assert!(reply.starts_with("ERR read-only"), "{reply}");
        assert!(roundtrip(addr, "LAG").contains("role=fenced"), "post-fence LAG");
        // reads still flow on the fenced node
        assert!(roundtrip(addr, "MATCH name=g").starts_with("OK "), "reads survive fencing");
    }

    #[test]
    fn replica_handshake_streams_baseline_and_takes_acks() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=120 seed=3").starts_with("OK "));
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"REPLICA epoch=0\n").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK epoch=0"), "{line}");
        // the baseline snapshot for the stored graph arrives first
        line.clear();
        r.read_line(&mut line).unwrap();
        let ev = replicate::parse_event(line.trim()).expect("baseline event");
        assert_eq!(ev.kind, EventKind::Snap);
        assert_eq!(ev.name, "g");
        assert!(
            crate::persist::snapshot::decode_snapshot(&ev.data).is_some(),
            "baseline must decode as a snapshot image"
        );
        // a write on the primary is streamed as a frame event
        let reply = roundtrip(addr, "UPDATE name=g addcols=0;1;2");
        assert!(reply.starts_with("OK "), "{reply}");
        line.clear();
        r.read_line(&mut line).unwrap();
        let ev = replicate::parse_event(line.trim()).expect("frame event");
        assert_eq!(ev.kind, EventKind::Frame);
        assert!(ev.seq > 0);
        // acking it moves the primary's lag back to zero
        s.write_all(format!("ACK seq={}\n", ev.seq).as_bytes()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let lag = roundtrip(addr, "LAG");
            if lag.contains("followers=1") && lag.contains(" lag=0 ") {
                break;
            }
            assert!(Instant::now() < deadline, "lag never drained: {lag}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn graceful_stop_drains_requests_and_loses_no_acked_update() {
        // the clean-SIGTERM regression: every UPDATE acked before the stop
        // must survive into a recovered server
        let dir = std::env::temp_dir().join(format!(
            "bimatch_server_drain_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let server =
            Server::bind_with("127.0.0.1:0", None, Some(dir.clone()), None).unwrap();
        let addr = server.local_addr().unwrap();
        let stop = server.stop_handle();
        let serve = std::thread::spawn(move || server.serve());
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=200 seed=5").starts_with("OK "));
        let mut card = String::new();
        for i in 0..5 {
            let reply = roundtrip(addr, &format!("UPDATE name=g addcols={i};{}", i + 1));
            assert!(reply.starts_with("OK "), "{reply}");
            card = reply
                .split_whitespace()
                .find_map(|kv| kv.strip_prefix("card="))
                .unwrap()
                .to_string();
        }
        // clean stop: serve() must return (drain + fsync) promptly
        stop.store(true, Ordering::Relaxed);
        serve.join().unwrap().unwrap();
        // a recovered server serves the exact acked state
        let server2 = Server::bind_with("127.0.0.1:0", None, Some(dir.clone()), None).unwrap();
        let addr2 = server2.local_addr().unwrap();
        std::thread::spawn(move || server2.serve());
        let reply = roundtrip(addr2, "MATCH name=g");
        assert!(reply.starts_with("OK "), "{reply}");
        assert!(reply.contains(&format!(" card={card} ")), "want card={card}: {reply}");
        assert!(reply.contains("certified=1"), "{reply}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Read a multi-line reply (`TRACE`, `METRICS`): lines up to the
    /// blank line that frames it.
    fn roundtrip_multi(addr: std::net::SocketAddr, req: &str) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(req.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        let mut r = BufReader::new(s);
        let mut out = Vec::new();
        loop {
            let mut line = String::new();
            if r.read_line(&mut line).unwrap() == 0 || line.trim().is_empty() {
                return out;
            }
            out.push(line.trim_end().to_string());
        }
    }

    #[test]
    fn trace_verb_streams_job_traces() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "MATCH family=uniform n=200 seed=1 algo=hk").starts_with("OK "));
        let lines = roundtrip_multi(addr, "TRACE");
        assert_eq!(lines[0], "TRACE n=1", "{lines:?}");
        assert_eq!(lines.len(), 2, "{lines:?}");
        let json = &lines[1];
        assert!(json.starts_with('{') && json.ends_with('}'), "{json}");
        assert!(json.contains("\"op\":\"match\""), "{json}");
        assert!(json.contains("\"algo\":\"hk\""), "{json}");
        assert!(json.contains("\"ok\":true"), "{json}");
        assert!(json.contains("\"spans\":["), "{json}");
        assert!(json.contains("\"name\":\"load\""), "{json}");
        assert!(json.contains("\"name\":\"solve\""), "{json}");
        assert!(json.contains("\"name\":\"certify\""), "{json}");
        // name= filters on the stored-graph name; one-shot jobs have none
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=150 seed=2").starts_with("OK "));
        assert!(roundtrip(addr, "MATCH name=g").starts_with("OK "));
        let lines = roundtrip_multi(addr, "TRACE name=g last=1");
        assert_eq!(lines[0], "TRACE n=1", "{lines:?}");
        assert!(lines[1].contains("\"graph\":\"g\""), "{}", lines[1]);
        assert!(lines[1].contains("\"op\":\"match\""), "newest first: {}", lines[1]);
        let lines = roundtrip_multi(addr, "TRACE name=ghost");
        assert_eq!(lines, vec!["TRACE n=0".to_string()]);
        assert!(roundtrip(addr, "TRACE last=wat").starts_with("ERR bad last"));
    }

    #[test]
    fn trace_verb_refused_when_ring_disarmed() {
        let mut cfg = ServerCfg::new("127.0.0.1:0");
        cfg.trace_capacity = 0;
        let server = Server::bind_cfg(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        std::thread::spawn(move || server.serve());
        assert!(roundtrip(addr, "TRACE").starts_with("ERR tracing disabled"));
    }

    #[test]
    fn metrics_verb_emits_prometheus_text() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "MATCH family=uniform n=200 seed=1 algo=hk").starts_with("OK "));
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=150 seed=2").starts_with("OK "));
        assert!(roundtrip(addr, "MATCH name=g").starts_with("OK "));
        let text = roundtrip_multi(addr, "METRICS").join("\n");
        assert!(text.contains("# TYPE bimatch_jobs_submitted_total counter"), "{text}");
        assert!(text.contains("bimatch_jobs_completed_total 3"), "{text}");
        assert!(text.contains("bimatch_job_latency_seconds_bucket{le="), "{text}");
        assert!(text.contains("bimatch_spec_jobs_total{spec=\"hk\"}"), "{text}");
        // the per-graph families carry the graph label
        assert!(text.contains("# TYPE bimatch_graph_matches_total counter"), "{text}");
        assert!(text.contains("bimatch_graph_matches_total{graph=\"g\"} 1"), "{text}");
    }

    #[test]
    fn stats_graph_reports_per_graph_breakdown() {
        let (addr, _stop) = start_server();
        assert!(roundtrip(addr, "LOAD name=g family=uniform n=200 seed=4").starts_with("OK "));
        assert!(roundtrip(addr, "MATCH name=g").starts_with("OK "));
        assert!(roundtrip(addr, "UPDATE name=g addcols=0;1;2").starts_with("OK "));
        let reply = roundtrip(addr, "STATS graph=g");
        assert!(reply.starts_with("STATS graph=g "), "{reply}");
        assert!(reply.contains("version="), "{reply}");
        assert!(reply.contains("matches=1"), "{reply}");
        assert!(reply.contains("recomputes=1"), "{reply}");
        assert!(reply.contains("updates=1"), "{reply}");
        assert!(reply.contains("cols_added=1"), "{reply}");
        // a volatile server never touches the WAL or snapshot files
        assert!(reply.contains("wal_appends=0"), "{reply}");
        assert!(roundtrip(addr, "STATS graph=ghost").starts_with("ERR"), "missing graph");
        // plain STATS still serves the process-wide line
        assert!(roundtrip(addr, "STATS").starts_with("STATS jobs:"));
    }

    #[test]
    fn slow_ms_threshold_counts_and_logs_slow_jobs() {
        let mut cfg = ServerCfg::new("127.0.0.1:0");
        cfg.slow_ms = Some(0); // everything is "slow"
        let server = Server::bind_cfg(cfg).unwrap();
        let addr = server.local_addr().unwrap();
        std::thread::spawn(move || server.serve());
        assert!(roundtrip(addr, "MATCH family=uniform n=150 seed=1 algo=hk").starts_with("OK "));
        let reply = roundtrip(addr, "STATS");
        assert!(reply.contains("slow=1"), "{reply}");
    }
}
