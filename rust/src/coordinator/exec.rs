//! Job execution: graph acquisition → cheap init → routing → matching →
//! certification → outcome. Shared by the worker pool and the TCP server.

use super::job::{AlgoChoice, GraphSource, MatchJob, MatchOutcome};
use super::metrics::Metrics;
use super::registry;
use super::router;
use crate::graph::csr::BipartiteCsr;
use crate::runtime::Engine;
use crate::util::timer::Timer;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Stateless executor (cheap to clone across workers).
#[derive(Clone)]
pub struct Executor {
    pub engine: Option<Arc<Engine>>,
    pub metrics: Arc<Metrics>,
}

impl Executor {
    pub fn new(engine: Option<Arc<Engine>>, metrics: Arc<Metrics>) -> Self {
        Self { engine, metrics }
    }

    fn acquire(&self, source: &GraphSource) -> Result<Arc<BipartiteCsr>, String> {
        match source {
            GraphSource::Generate { family, n, seed, permute } => {
                let g = family.generate(*n, *seed);
                Ok(Arc::new(if *permute {
                    crate::graph::random_permute(&g, seed.wrapping_add(0x5EED))
                } else {
                    g
                }))
            }
            GraphSource::MtxFile(path) => crate::graph::mtx::read_mtx(std::path::Path::new(path))
                .map(Arc::new)
                .map_err(|e| format!("reading {path}: {e}")),
            GraphSource::InMemory(g) => Ok(g.clone()),
        }
    }

    pub fn execute(&self, job: &MatchJob) -> MatchOutcome {
        let total = Timer::start();
        let mut out = MatchOutcome {
            job_id: job.id,
            algo: String::new(),
            nr: 0,
            nc: 0,
            n_edges: 0,
            cardinality: 0,
            init_cardinality: 0,
            certified: false,
            t_load: 0.0,
            t_init: 0.0,
            t_match: 0.0,
            phases: 0,
            error: None,
        };
        let g = match self.acquire(&job.source) {
            Ok(g) => g,
            Err(e) => {
                out.error = Some(e);
                self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                return out;
            }
        };
        out.t_load = total.elapsed_secs();
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();

        let t_init = Timer::start();
        let init = job.init.run(&g);
        out.t_init = t_init.elapsed_secs();
        out.init_cardinality = init.cardinality();

        let mut name = match &job.algo {
            AlgoChoice::Auto => router::route_graph(&g).to_string(),
            AlgoChoice::Named(n) => n.clone(),
        };
        // frontier override: normalize the "-FC" suffix of a GPU pick to
        // the requested mode, after routing — CPU picks stay untouched,
        // so `--frontier fullscan` overrides the router's "-FC" default
        // without forcing a GPU algorithm onto pfp/dfs-routed graphs
        if let Some(fm) = job.frontier {
            if name == "gpu" || name.starts_with("gpu:") {
                use crate::gpu::{FrontierMode, GpuConfig};
                let base = if name == "gpu" {
                    format!("gpu:{}", GpuConfig::default().name())
                } else {
                    name.clone()
                };
                let stripped = base.strip_suffix("-FC").unwrap_or(&base);
                name = match fm {
                    FrontierMode::Compacted => format!("{stripped}-FC"),
                    FrontierMode::FullScan => stripped.to_string(),
                };
            }
        }
        let Some(algo) = registry::build(&name, self.engine.clone()) else {
            out.error = Some(format!("unknown algorithm {name}"));
            self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            return out;
        };
        out.algo = algo.name();

        let t_match = Timer::start();
        let result = algo.run(&g, init);
        out.t_match = t_match.elapsed_secs();
        out.cardinality = result.matching.cardinality();
        out.phases = result.stats.phases;

        if job.certify {
            match result.matching.certify(&g) {
                Ok(()) => out.certified = true,
                Err(e) => {
                    // a job whose result fails certification is a *failed*
                    // job: it must not count as completed nor contribute
                    // its (untrusted) cardinality to matched_total, so
                    // `submitted == completed + failed` stays an invariant
                    out.error = Some(format!("certification failed: {e}"));
                    self.metrics.certify_failures.fetch_add(1, Ordering::Relaxed);
                    self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    return out;
                }
            }
        }

        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .edges_processed
            .fetch_add(out.n_edges as u64, Ordering::Relaxed);
        self.metrics
            .matched_total
            .fetch_add(out.cardinality as u64, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::MatchJob;
    use crate::graph::gen::Family;

    fn exec() -> Executor {
        Executor::new(None, Arc::new(Metrics::new()))
    }

    #[test]
    fn executes_generated_job_auto_routing() {
        let job = MatchJob::new(
            1,
            GraphSource::Generate { family: Family::Uniform, n: 500, seed: 2, permute: false },
        );
        let out = exec().execute(&job);
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.certified);
        assert!(out.cardinality > 0);
        assert!(out.cardinality >= out.init_cardinality);
        assert!(!out.algo.is_empty());
    }

    #[test]
    fn named_algorithm_respected() {
        let job = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Banded, n: 300, seed: 1, permute: true },
        )
        .with_algo("hkdw");
        let out = exec().execute(&job);
        assert_eq!(out.algo, "hkdw");
        assert!(out.certified);
    }

    #[test]
    fn unknown_algorithm_is_error() {
        let job = MatchJob::new(
            3,
            GraphSource::Generate { family: Family::Uniform, n: 50, seed: 1, permute: false },
        )
        .with_algo("bogus");
        let out = exec().execute(&job);
        assert!(out.error.as_deref().unwrap_or("").contains("unknown"));
    }

    #[test]
    fn missing_mtx_is_error_not_panic() {
        let job = MatchJob::new(4, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let out = exec().execute(&job);
        assert!(out.error.is_some());
    }

    #[test]
    fn frontier_override_normalizes_gpu_picks_only() {
        use crate::gpu::FrontierMode;
        let mk = |seed| {
            MatchJob::new(
                seed,
                GraphSource::Generate { family: Family::Uniform, n: 200, seed, permute: false },
            )
        };
        // explicit "gpu" alias + compacted → the "-FC" twin runs
        let out = exec().execute(&mk(0).with_algo("gpu").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "gpu:APFB-GPUBFS-WR-CT-FC");
        assert!(out.certified);
        // an "-FC" name + fullscan override → suffix stripped
        let job = mk(1).with_algo("gpu:APsB-GPUBFS-CT-FC").with_frontier(FrontierMode::FullScan);
        let out = exec().execute(&job);
        assert_eq!(out.algo, "gpu:APsB-GPUBFS-CT");
        // CPU picks are untouched by the override
        let out = exec().execute(&mk(2).with_algo("pfp").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "pfp");
        assert!(out.certified);
    }

    #[test]
    fn in_memory_source() {
        let g = Arc::new(crate::graph::from_edges(2, 2, &[(0, 0), (1, 1)]));
        let job = MatchJob::new(5, GraphSource::InMemory(g)).with_algo("bfs");
        let out = exec().execute(&job);
        assert_eq!(out.cardinality, 2);
        assert!(out.certified);
    }

    #[test]
    fn metrics_accumulate() {
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        for i in 0..3 {
            let job = MatchJob::new(
                i,
                GraphSource::Generate { family: Family::Uniform, n: 100, seed: i, permute: false },
            );
            e.execute(&job);
        }
        assert_eq!(metrics.completed(), 3);
        assert!(metrics.mean_latency() > 0.0);
    }

    #[test]
    fn failed_jobs_do_not_pollute_completion_metrics() {
        // every failure path (acquire, unknown algo) must land in
        // jobs_failed and leave jobs_completed / matched_total untouched,
        // so submitted == completed + failed stays an invariant (the
        // certification-failure path shares the same early return)
        use std::sync::atomic::Ordering;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let bad_algo = MatchJob::new(
            0,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 1, permute: false },
        )
        .with_algo("no-such-algo");
        let missing = MatchJob::new(1, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let good = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 2, permute: false },
        );
        for job in [&bad_algo, &missing, &good] {
            e.execute(job);
        }
        assert_eq!(metrics.completed(), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 2);
        let good_card = e.execute(&good).cardinality as u64;
        assert_eq!(
            metrics.matched_total.load(Ordering::Relaxed),
            2 * good_card,
            "only certified-complete jobs contribute to matched_total"
        );
    }
}
