//! Job execution: graph acquisition → cheap init → routing → matching →
//! certification → outcome. Shared by the worker pool and the TCP server.
//!
//! The executor owns the serving-layer context every run gets: a shared
//! [`WorkspacePool`] (scratch buffers reused across jobs), a
//! [`CancelToken`] covering all in-flight runs, and the per-job deadline
//! (`MatchJob::timeout`, measured from the start of execution). A tripped
//! run is a *distinct* failure ([`JobError::DeadlineExceeded`] /
//! [`JobError::Cancelled`]) — never a silently suboptimal answer.

use super::job::{AlgoChoice, GraphSource, JobError, MatchJob, MatchOutcome};
use super::metrics::Metrics;
use super::registry;
use super::router;
use crate::graph::csr::BipartiteCsr;
use crate::matching::algo::{CancelToken, RunCtx, RunOutcome};
use crate::runtime::Engine;
use crate::util::pool::WorkspacePool;
use crate::util::timer::Timer;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Stateless-per-job executor (cheap to clone across workers; clones share
/// the workspace pool and the cancellation token).
#[derive(Clone)]
pub struct Executor {
    pub engine: Option<Arc<Engine>>,
    pub metrics: Arc<Metrics>,
    pool: Arc<WorkspacePool>,
    cancel: CancelToken,
}

impl Executor {
    pub fn new(engine: Option<Arc<Engine>>, metrics: Arc<Metrics>) -> Self {
        Self {
            engine,
            metrics,
            pool: Arc::new(WorkspacePool::new()),
            cancel: CancelToken::new(),
        }
    }

    /// The shared scratch-buffer pool (observability + tests).
    pub fn workspace_pool(&self) -> &Arc<WorkspacePool> {
        &self.pool
    }

    /// Token cancelling every in-flight and future run of this executor
    /// (and its clones) at the next inter-phase checkpoint.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    fn acquire(&self, source: &GraphSource) -> Result<Arc<BipartiteCsr>, String> {
        match source {
            GraphSource::Generate { family, n, seed, permute } => {
                let g = family.generate(*n, *seed);
                Ok(Arc::new(if *permute {
                    crate::graph::random_permute(&g, seed.wrapping_add(0x5EED))
                } else {
                    g
                }))
            }
            GraphSource::MtxFile(path) => crate::graph::mtx::read_mtx(std::path::Path::new(path))
                .map(Arc::new)
                .map_err(|e| format!("reading {path}: {e}")),
            GraphSource::InMemory(g) => Ok(g.clone()),
        }
    }

    pub fn execute(&self, job: &MatchJob) -> MatchOutcome {
        let total = Timer::start();
        // the deadline covers the whole job: load + init + matching
        let deadline = job.timeout.map(|budget| Instant::now() + budget);
        let mut out = MatchOutcome {
            job_id: job.id,
            algo: String::new(),
            nr: 0,
            nc: 0,
            n_edges: 0,
            cardinality: 0,
            init_cardinality: 0,
            certified: false,
            t_load: 0.0,
            t_init: 0.0,
            t_match: 0.0,
            phases: 0,
            frontier_peak: 0,
            endpoints_total: 0,
            device_parallel_cycles: 0,
            error: None,
        };
        let fail = |out: &mut MatchOutcome, err: JobError| {
            out.error = Some(err);
            self.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
        };
        let g = match self.acquire(&job.source) {
            Ok(g) => g,
            Err(e) => {
                fail(&mut out, JobError::Load(e));
                return out;
            }
        };
        out.t_load = total.elapsed_secs();
        out.nr = g.nr;
        out.nc = g.nc;
        out.n_edges = g.n_edges();

        let t_init = Timer::start();
        let init = job.init.run(&g);
        out.t_init = t_init.elapsed_secs();
        out.init_cardinality = init.cardinality();

        let mut spec = match &job.algo {
            AlgoChoice::Auto => router::route_graph(&g),
            AlgoChoice::Spec(s) => *s,
        };
        // frontier override as a typed field edit, applied *after* routing:
        // a GPU pick (named or auto-routed) gets the requested mode while
        // CPU-routed graphs keep their pfp/dfs pick — so `--frontier
        // fullscan` forces the paper-faithful variant only where a GPU
        // algorithm actually runs
        if let Some(fm) = job.frontier {
            spec.set_frontier(fm);
        }
        out.algo = spec.to_string();
        let Some(algo) = registry::build(&spec, self.engine.clone()) else {
            fail(&mut out, JobError::Unavailable(registry::unavailable_msg(&spec)));
            return out;
        };
        out.algo = algo.name();

        let mut ctx = RunCtx::new(self.pool.clone()).with_cancel(self.cancel.clone());
        ctx.set_deadline(deadline);
        let t_match = Timer::start();
        let result = algo.run(&g, init, &mut ctx);
        out.t_match = t_match.elapsed_secs();
        out.cardinality = result.matching.cardinality();
        out.phases = result.stats.phases;
        out.frontier_peak = result.stats.frontier_peak;
        out.endpoints_total = result.stats.endpoints_total;
        out.device_parallel_cycles = result.stats.device_parallel_cycles;

        match result.outcome {
            RunOutcome::Complete => {}
            RunOutcome::DeadlineExceeded => {
                let timeout_ms = job.timeout.map(|d| d.as_millis() as u64).unwrap_or(0);
                self.metrics.jobs_timed_out.fetch_add(1, Ordering::Relaxed);
                fail(&mut out, JobError::DeadlineExceeded { timeout_ms });
                return out;
            }
            RunOutcome::Cancelled => {
                self.metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                fail(&mut out, JobError::Cancelled);
                return out;
            }
        }

        if job.certify {
            match result.matching.certify(&g) {
                Ok(()) => out.certified = true,
                Err(e) => {
                    // a job whose result fails certification is a *failed*
                    // job: it must not count as completed nor contribute
                    // its (untrusted) cardinality to matched_total, so
                    // `submitted == completed + failed` stays an invariant
                    self.metrics.certify_failures.fetch_add(1, Ordering::Relaxed);
                    fail(&mut out, JobError::Certify(e));
                    return out;
                }
            }
        }

        self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .edges_processed
            .fetch_add(out.n_edges as u64, Ordering::Relaxed);
        self.metrics
            .matched_total
            .fetch_add(out.cardinality as u64, Ordering::Relaxed);
        self.metrics.observe_latency(total.elapsed_secs());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::MatchJob;
    use crate::graph::gen::Family;

    fn exec() -> Executor {
        Executor::new(None, Arc::new(Metrics::new()))
    }

    #[test]
    fn executes_generated_job_auto_routing() {
        let job = MatchJob::new(
            1,
            GraphSource::Generate { family: Family::Uniform, n: 500, seed: 2, permute: false },
        );
        let out = exec().execute(&job);
        assert!(out.error.is_none(), "{:?}", out.error);
        assert!(out.certified);
        assert!(out.cardinality > 0);
        assert!(out.cardinality >= out.init_cardinality);
        assert!(!out.algo.is_empty());
    }

    #[test]
    fn named_algorithm_respected() {
        let job = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Banded, n: 300, seed: 1, permute: true },
        )
        .with_algo("hkdw");
        let out = exec().execute(&job);
        assert_eq!(out.algo, "hkdw");
        assert!(out.certified);
    }

    #[test]
    fn unavailable_backend_is_a_distinct_error() {
        // xla specs parse fine but cannot build without an engine
        let job = MatchJob::new(
            3,
            GraphSource::Generate { family: Family::Uniform, n: 50, seed: 1, permute: false },
        )
        .with_algo("xla:apfb-full");
        let out = exec().execute(&job);
        assert!(matches!(out.error, Some(JobError::Unavailable(_))), "{:?}", out.error);
        assert_eq!(out.algo, "xla:apfb-full");
    }

    #[test]
    fn missing_mtx_is_error_not_panic() {
        let job = MatchJob::new(4, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let out = exec().execute(&job);
        assert!(matches!(out.error, Some(JobError::Load(_))));
    }

    #[test]
    fn frontier_override_normalizes_gpu_picks_only() {
        use crate::gpu::FrontierMode;
        let mk = |seed| {
            MatchJob::new(
                seed,
                GraphSource::Generate { family: Family::Uniform, n: 200, seed, permute: false },
            )
        };
        // explicit "gpu" alias + compacted → the "-FC" twin runs
        let out = exec().execute(&mk(0).with_algo("gpu").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "gpu:APFB-GPUBFS-WR-CT-FC");
        assert!(out.certified);
        // an "-FC" name + fullscan override → compaction disabled
        let job = mk(1).with_algo("gpu:APsB-GPUBFS-CT-FC").with_frontier(FrontierMode::FullScan);
        let out = exec().execute(&job);
        assert_eq!(out.algo, "gpu:APsB-GPUBFS-CT");
        // CPU picks are untouched by the override
        let out = exec().execute(&mk(2).with_algo("pfp").with_frontier(FrontierMode::Compacted));
        assert_eq!(out.algo, "pfp");
        assert!(out.certified);
    }

    #[test]
    fn in_memory_source() {
        let g = Arc::new(crate::graph::from_edges(2, 2, &[(0, 0), (1, 1)]));
        let job = MatchJob::new(5, GraphSource::InMemory(g)).with_algo("bfs");
        let out = exec().execute(&job);
        assert_eq!(out.cardinality, 2);
        assert!(out.certified);
    }

    #[test]
    fn metrics_accumulate() {
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        for i in 0..3 {
            let job = MatchJob::new(
                i,
                GraphSource::Generate { family: Family::Uniform, n: 100, seed: i, permute: false },
            );
            e.execute(&job);
        }
        assert_eq!(metrics.completed(), 3);
        assert!(metrics.mean_latency() > 0.0);
    }

    #[test]
    fn failed_jobs_do_not_pollute_completion_metrics() {
        // every failure path (acquire, unbuildable algo) must land in
        // jobs_failed and leave jobs_completed / matched_total untouched,
        // so submitted == completed + failed stays an invariant (the
        // certification-failure path shares the same early return)
        use std::sync::atomic::Ordering;
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let bad_algo = MatchJob::new(
            0,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 1, permute: false },
        )
        .with_algo("xla:apfb-full"); // no engine → unavailable
        let missing = MatchJob::new(1, GraphSource::MtxFile("/no/such/file.mtx".into()));
        let good = MatchJob::new(
            2,
            GraphSource::Generate { family: Family::Uniform, n: 100, seed: 2, permute: false },
        );
        for job in [&bad_algo, &missing, &good] {
            e.execute(job);
        }
        assert_eq!(metrics.completed(), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 2);
        let good_card = e.execute(&good).cardinality as u64;
        assert_eq!(
            metrics.matched_total.load(Ordering::Relaxed),
            2 * good_card,
            "only certified-complete jobs contribute to matched_total"
        );
    }

    #[test]
    fn timed_out_job_fails_distinctly() {
        // a zero deadline trips at the first inter-phase checkpoint, for
        // every backend the job could route to
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        let job = MatchJob::new(
            9,
            GraphSource::Generate { family: Family::Uniform, n: 800, seed: 3, permute: false },
        )
        .with_algo("hk")
        .with_timeout_ms(0);
        let out = e.execute(&job);
        assert_eq!(out.error, Some(JobError::DeadlineExceeded { timeout_ms: 0 }));
        assert!(!out.certified);
        assert_eq!(metrics.jobs_timed_out.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed(), 0);
    }

    #[test]
    fn cancelled_executor_fails_jobs_distinctly() {
        let metrics = Arc::new(Metrics::new());
        let e = Executor::new(None, metrics.clone());
        e.cancel_token().cancel();
        let job = MatchJob::new(
            10,
            GraphSource::Generate { family: Family::Uniform, n: 400, seed: 1, permute: false },
        )
        .with_algo("pfp");
        let out = e.execute(&job);
        assert_eq!(out.error, Some(JobError::Cancelled));
        assert_eq!(metrics.jobs_cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn workspace_pool_reused_across_jobs() {
        // the acceptance bar for workspace reuse: a second same-size job
        // through the same executor leases the first job's buffers
        let e = exec();
        let mk = |id| {
            MatchJob::new(
                id,
                GraphSource::Generate { family: Family::Uniform, n: 400, seed: 7, permute: false },
            )
            .with_algo("gpu:APFB-GPUBFS-WR-CT-FC")
        };
        let out = e.execute(&mk(0));
        assert!(out.certified, "{:?}", out.error);
        assert_eq!(e.workspace_pool().reuses(), 0, "first job allocates fresh");
        let returned = e.workspace_pool().returns();
        assert!(returned > 0, "buffers must come back to the pool");
        let out = e.execute(&mk(1));
        assert!(out.certified);
        assert!(
            e.workspace_pool().reuses() >= 3,
            "second same-size job must lease the first job's buffers, reuses={}",
            e.workspace_pool().reuses()
        );
    }
}
